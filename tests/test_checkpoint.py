"""Checkpointing: bit-exact resume, async save, retention, atomicity, and
elastic restore onto a different mesh (subprocess with 8 fake devices)."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, restore_state, save_state
from repro.data import TokenBatchPipeline, write_token_corpus
from repro.core.cache import DifferentialCache
from repro.core.planner import ScanExecutor
from repro.lake.catalog import Catalog
from repro.lake.s3sim import ObjectStore
from repro.models.registry import get_config, get_model
from repro.train.loop import make_init_state, make_train_step
from repro.train.optimizer import OptimizerConfig
from repro.train.state import TrainState


def _setup_training(tmp_path, arch="granite-3-2b"):
    cfg = get_config(arch).reduced()
    api = get_model(cfg)
    opt = OptimizerConfig(kind="adamw", peak_lr=1e-3)
    store = ObjectStore(str(tmp_path / "s3"))
    catalog = Catalog(store, rows_per_fragment=8192)
    write_token_corpus(catalog, "data.c", 20_000, cfg.vocab_size, seed=3)
    scans = ScanExecutor(store, catalog, cache=DifferentialCache())
    pipe = TokenBatchPipeline(
        scans, "data.c", global_batch=4, seq_len=64, prefetch_depth=0
    )
    step_fn = jax.jit(make_train_step(api, opt))
    state = make_init_state(api, opt)(jax.random.PRNGKey(0))
    return api, step_fn, state, pipe


def _run_steps(step_fn, state, pipe, start, n):
    metrics = []
    for s in range(start, start + n):
        state, m = step_fn(state, pipe.batch_at(s))
        metrics.append(float(m["loss"]))
    return state, metrics


def _trees_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_bit_exact_resume(tmp_path):
    api, step_fn, state, pipe = _setup_training(tmp_path)
    # uninterrupted: 5 steps
    ref_state, ref_losses = _run_steps(step_fn, state, pipe, 0, 5)
    # interrupted: 3 steps, save, restore, 2 more
    s3, _ = _run_steps(step_fn, state, pipe, 0, 3)
    save_state(str(tmp_path / "ckpt"), 3, s3)
    step, restored = restore_state(str(tmp_path / "ckpt"), target_struct=s3)
    assert step == 3
    _trees_equal(s3, restored)
    final, losses = _run_steps(step_fn, restored, pipe, 3, 2)
    _trees_equal(ref_state, final)
    np.testing.assert_allclose(losses, ref_losses[3:], rtol=0, atol=0)


def test_async_save_matches_blocking(tmp_path):
    _api, _fn, state, _pipe = _setup_training(tmp_path)
    t = save_state(str(tmp_path / "a"), 1, state, blocking=False)
    t.join()
    save_state(str(tmp_path / "b"), 1, state, blocking=True)
    _, ra = restore_state(str(tmp_path / "a"))
    _, rb = restore_state(str(tmp_path / "b"))
    _trees_equal(ra, rb)


def test_async_save_snapshot_isolated_from_donation(tmp_path):
    """The host snapshot is taken before save() returns: mutating (donating)
    the state right after must not corrupt the checkpoint."""
    state = {"w": jnp.arange(8, dtype=jnp.float32)}
    want = np.asarray(state["w"]).copy()
    t = save_state(str(tmp_path / "c"), 7, state, blocking=False)
    state["w"] = state["w"] * 0 - 1  # "donated"/reused buffer
    t.join()
    _, r = restore_state(str(tmp_path / "c"))
    np.testing.assert_array_equal(r["w"], want)


def test_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=2, async_save=False)
    state = {"x": jnp.ones((4,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.steps() == [3, 4]
    assert mgr.latest() == 4


def test_incomplete_tmp_dirs_ignored(tmp_path):
    root = tmp_path / "ck"
    mgr = CheckpointManager(str(root), keep=3, async_save=False)
    mgr.save(1, {"x": jnp.ones(2)})
    # simulate a crash mid-save
    os.makedirs(root / "step-9.tmp-deadbeef")
    (root / "step-9.tmp-deadbeef" / "junk.npy").write_bytes(b"xx")
    os.makedirs(root / "step-5")  # complete-looking dir without manifest
    assert mgr.steps() == [1]
    step, _ = mgr.restore()
    assert step == 1


def test_extra_metadata_roundtrip(tmp_path):
    save_state(str(tmp_path / "ck"), 2, {"x": jnp.zeros(1)}, extra={"data_step": 17})
    import json

    with open(tmp_path / "ck" / "step-2" / "manifest.json") as f:
        m = json.load(f)
    assert m["extra"]["data_step"] == 17


_ELASTIC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.checkpoint import restore_state, save_state

    root = sys.argv[1]
    devs = np.array(jax.devices())

    # save under a 4x2 mesh
    mesh_a = Mesh(devs[:8].reshape(4, 2), ("data", "model"))
    w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    w = jax.device_put(w, NamedSharding(mesh_a, P("data", "model")))
    save_state(root, 1, {"w": w})

    # restore under a 2x4 mesh (different axis sizes) — elastic reshard
    mesh_b = Mesh(devs[:8].reshape(2, 4), ("data", "model"))
    sh = {"w": NamedSharding(mesh_b, P("data", "model"))}
    step, tree = restore_state(root, shardings=sh)
    assert step == 1
    got = np.asarray(tree["w"])
    np.testing.assert_array_equal(got, np.arange(64, dtype=np.float32).reshape(8, 8))
    assert tree["w"].sharding.mesh.shape["data"] == 2
    print("ELASTIC_OK")
    """
)


def test_elastic_restore_different_mesh(tmp_path):
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", _ELASTIC, str(tmp_path / "ck")],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)),
        timeout=300,
    )
    assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]
