"""Column-scope wiring (ISSUE 7): signature narrowing, UNKNOWN fallback,
and plan-time scope enforcement.

The headline property: when a model's read scope is *proven* (or declared),
adding a column the function never reads must leave every cached window
valid — the warm run recomputes nothing and stays bitwise-equal to a cold
run.  With an UNKNOWN scope the signature is byte-identical to the
pre-analysis behavior (sound fallback: never narrower than the truth)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.analysis import ScopeViolation
from repro.pipeline import Model, Project, Workspace, model, runtime
from repro.pipeline.filters import parse_filter
from repro.pipeline.physical import _signature_columns
from repro.service import PipelineService
from test_service import (
    TABLE,
    assert_outputs_bitwise_equal,
    write_events,
)


def scoped_project(hi, columns=("v1",), gain=2.0, opaque=False):
    """One rowwise model over ns.events.  ``opaque=False``: the function
    provably reads only eventTime+v1, so its scope narrows the signature.
    ``opaque=True``: a dynamic ``data.column(n)`` loop defeats inference
    (reads UNKNOWN) — the pre-analysis "today" baseline."""
    p = Project("scoped")
    flt = f"eventTime BETWEEN 0 AND {hi}"

    if opaque:

        @model(project=p, incremental="rowwise")
        @runtime("numpy")
        def scored(data=Model(TABLE, columns=list(columns), filter=flt)):
            out = {}
            for n in data.column_names:  # dynamic key: scope is UNKNOWN
                out[n] = data.column(n)
            out["score"] = gain * np.asarray(data.column("v1"), np.float64)
            return out

    else:

        @model(project=p, incremental="rowwise")
        @runtime("numpy")
        def scored(data=Model(TABLE, columns=list(columns), filter=flt)):
            return {
                "eventTime": data.column("eventTime"),
                "score": gain * np.asarray(data.column("v1"), np.float64),
            }

    return p


# ----------------------------------------------------------- unit: narrowing
class _StubDef:
    def __init__(self, scope):
        self.read_scope = scope


PARSED = parse_filter("eventTime BETWEEN 0 AND 9", "eventTime")


def test_signature_columns_narrow_to_scope():
    got = _signature_columns(
        _StubDef(frozenset({"v1"})), ("v1", "v2", "flag"), PARSED, "eventTime"
    )
    assert got == ("eventTime", "v1")


def test_signature_columns_unknown_scope_is_identity():
    cols = ("flag", "v1", "v2")
    assert _signature_columns(_StubDef(None), cols, PARSED, "eventTime") is cols


def test_signature_columns_keep_predicate_and_sort_key():
    # predicate/sort-key columns shape the ROWS, so they stay in the
    # signature even when the function never reads them
    got = _signature_columns(_StubDef(frozenset()), ("v1",), PARSED, "eventTime")
    assert got == ("eventTime",)


@settings(max_examples=40)
@given(st.sets(st.sampled_from(["v2", "flag", "w1", "w2", "w3"]), max_size=5))
def test_signature_invariant_under_unread_columns(extra):
    """Round-trip property: for a proven scope, ANY set of unread columns
    added to the projection leaves the signature tuple unchanged."""
    scope = frozenset({"v1"})
    base = _signature_columns(_StubDef(scope), ("v1",), PARSED, "eventTime")
    widened = _signature_columns(
        _StubDef(scope), tuple(sorted({"v1"} | extra)), PARSED, "eventTime"
    )
    assert widened == base


@settings(max_examples=40)
@given(st.sets(st.sampled_from(["v2", "flag", "w1", "w2"]), max_size=4))
def test_unknown_scope_round_trips_exact_columns(extra):
    """UNKNOWN fallback: the signature is exactly the projection — adding a
    column changes it (conservative: plans identical to pre-analysis)."""
    cols = tuple(sorted({"v1"} | extra))
    assert _signature_columns(_StubDef(None), cols, PARSED, "eventTime") == cols


# -------------------------------------------- integration: feature-add reuse
def test_feature_add_on_unread_column_serves_from_cache(tmp_path):
    ws = Workspace(str(tmp_path / "ws"), rows_per_fragment=256)
    write_events(ws.catalog, 0, 2000)

    cold = ws.run(scoped_project(hi=1999, columns=("v1",)))
    assert cold.node_stats["scored"]["fresh_rows"] > 0

    # feature-add: project v2 too — the fn provably never reads it, so the
    # node signature is unchanged and the cached windows stay valid
    warm = ws.run(scoped_project(hi=1999, columns=("v1", "v2")))
    assert warm.rows_to_user_fns == 0
    assert warm.node_stats["scored"]["fresh_rows"] == 0

    ref = Workspace(str(tmp_path / "ref"), rows_per_fragment=256)
    write_events(ref.catalog, 0, 2000)
    assert_outputs_bitwise_equal(
        warm, ref.run(scoped_project(hi=1999, columns=("v1", "v2")))
    )


def test_feature_add_with_unknown_scope_recomputes(tmp_path):
    """The pre-analysis baseline: an opaque function's signature carries the
    full projection, so the same feature-add invalidates everything."""
    ws = Workspace(str(tmp_path / "ws"), rows_per_fragment=256)
    write_events(ws.catalog, 0, 2000)

    ws.run(scoped_project(hi=1999, columns=("v1",), opaque=True))
    warm = ws.run(scoped_project(hi=1999, columns=("v1", "v2"), opaque=True))
    assert warm.node_stats["scored"]["fresh_rows"] > 0


def test_unchanged_project_still_fully_cached(tmp_path):
    # narrowing must not break the ordinary warm path
    ws = Workspace(str(tmp_path / "ws"), rows_per_fragment=256)
    write_events(ws.catalog, 0, 2000)
    ws.run(scoped_project(hi=1999))
    warm = ws.run(scoped_project(hi=1999))
    assert warm.rows_to_user_fns == 0


# ----------------------------------------------- plan-time scope enforcement
def test_enforcement_rejects_out_of_scope_read(tmp_path):
    ws = Workspace(str(tmp_path / "ws"), rows_per_fragment=256, enforce_scopes=True)
    write_events(ws.catalog, 0, 1000)

    # the projection requests v2 but the function's proven scope never
    # reads it — rejected at plan time, before a single byte moves
    with pytest.raises(ScopeViolation, match="v2"):
        ws.run(scoped_project(hi=999, columns=("v1", "v2")))
    assert ws.scans.total_bytes_processed() == 0


def test_enforcement_rejects_unknown_scope(tmp_path):
    ws = Workspace(str(tmp_path / "ws"), rows_per_fragment=256, enforce_scopes=True)
    write_events(ws.catalog, 0, 1000)

    with pytest.raises(ScopeViolation, match="UNKNOWN"):
        ws.run(scoped_project(hi=999, opaque=True))
    assert ws.scans.total_bytes_processed() == 0


def test_enforcement_allows_proven_in_scope_run(tmp_path):
    ws = Workspace(str(tmp_path / "ws"), rows_per_fragment=256, enforce_scopes=True)
    write_events(ws.catalog, 0, 1000)
    res = ws.run(scoped_project(hi=999, columns=("v1",)))

    ref = Workspace(str(tmp_path / "ref"), rows_per_fragment=256)
    write_events(ref.catalog, 0, 1000)
    assert_outputs_bitwise_equal(res, ref.run(scoped_project(hi=999)))


def test_enforcement_accepts_declared_scope_for_opaque_fn(tmp_path):
    """An opaque function can still run under enforcement by DECLARING its
    scope — the decorator has already checked the declaration is a superset
    of anything provable, so the plan gate trusts it."""
    ws = Workspace(str(tmp_path / "ws"), rows_per_fragment=256, enforce_scopes=True)
    write_events(ws.catalog, 0, 1000)
    p = Project("declared")

    @model(project=p, incremental="rowwise", reads=("eventTime", "v1"))
    @runtime("numpy")
    def scored(
        data=Model(TABLE, columns=["v1"], filter="eventTime BETWEEN 0 AND 999")
    ):
        out = {}
        for n in data.column_names:
            out[n] = data.column(n)
        out["score"] = 2.0 * np.asarray(data.column("v1"), np.float64)
        return out

    res = ws.run(p)
    assert res.outputs["scored"].num_rows == 1000


def test_service_untrusted_session_enforces_scopes(tmp_path):
    with PipelineService(
        str(tmp_path / "svc"), workers=2, rows_per_fragment=256
    ) as svc:
        write_events(svc.catalog, 0, 1000)
        # trusted session: UNKNOWN scope is fine
        svc.session("alice").run(scoped_project(hi=999, opaque=True))
        # untrusted session: same project is rejected at plan time
        with pytest.raises(ScopeViolation):
            svc.session("mallory", untrusted=True).run(
                scoped_project(hi=999, opaque=True)
            )


def test_service_enforce_scopes_default_with_trusted_override(tmp_path):
    with PipelineService(
        str(tmp_path / "svc"), workers=2, rows_per_fragment=256, enforce_scopes=True
    ) as svc:
        write_events(svc.catalog, 0, 1000)
        with pytest.raises(ScopeViolation):
            svc.session("bob").run(scoped_project(hi=999, opaque=True))
        # explicit trusted override wins over the service default
        res = svc.session("root", untrusted=False).run(
            scoped_project(hi=999, opaque=True)
        )
        assert res.outputs["scored"].num_rows == 1000


# ------------------------------------------------------- bench7 acceptance
def test_bench7_acceptance():
    from benchmarks import bench7_scopes as b7

    result = b7.run(rows=4000)
    scoped = result["scoped_feature_add"]
    assert scoped["warm_fresh_rows"] <= 0.01 * scoped["cold_fresh_rows"]
    assert scoped["bitwise_equal"]
    assert result["opaque_feature_add"]["warm_fresh_rows"] > 0
    assert result["enforcement"]["rejected"]
    assert result["enforcement"]["bytes_read"] == 0
