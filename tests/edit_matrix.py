"""Reusable edit-matrix harness for incrementality contracts (ISSUE 6).

Every incrementality contract (rowwise, multi-input rowwise, keyed) must
satisfy ONE property: a warm workspace driven through an arbitrary sequence
of pipeline edits produces outputs **bitwise-identical** to a cold workspace
that replayed the same catalog history, while never feeding user functions
more rows than the cold run did.  This module is that property, factored out
of ``test_incremental.py`` so each contract instantiates the same sweep:

- :class:`Edit` — one step of the matrix: project-factory parameters, an
  optional catalog mutation applied *before* the run, an optional snapshot
  time-travel target, and an optional extra expectation on the ledgers.
- :func:`sweep` — drives one long-lived warm workspace through the edit
  sequence; for every edit it replays the identical catalog history into a
  fresh cold workspace and asserts bitwise equality + ledger sanity.
- :func:`standard_matrix` — the canonical axis sweep from the paper's §II
  iteration loop: identical rerun, window widen/narrow/beyond-data, feature
  add/remove, upstream append, range overwrite, code edit, snapshot travel.

The warm workspace is deliberately SEQUENTIAL through all edits (unlike one
fresh workspace per test): cache state accumulated by earlier edits must
never leak into later answers, which is the strictest version of the
bitwise-equivalence gate.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "Edit",
    "assert_outputs_bitwise_equal",
    "expect_fresh_rows",
    "expect_fresh_rows_between",
    "expect_zero_rows",
    "standard_matrix",
    "sweep",
]


@dataclass
class Edit:
    """One step of the edit matrix.

    ``params`` go to the test's project factory (the edit axes: window
    bounds, projected columns, code constants).  ``mutate`` is applied to
    the warm catalog before the run and recorded into the history every
    cold reference replays.  ``travel_to`` pins the run to the snapshot
    state after the first N mutations (0 = the seeded state), exercising
    time travel against a warm cache that has already seen newer data.
    ``expect(warm_res, cold_res)`` adds contract-specific ledger
    assertions (exact residual row counts, zero-recompute guarantees).
    """

    label: str
    params: Dict = field(default_factory=dict)
    mutate: Optional[Callable] = None
    travel_to: Optional[int] = None
    expect: Optional[Callable] = None


def assert_outputs_bitwise_equal(res_a, res_b):
    assert set(res_a.outputs) == set(res_b.outputs)
    for name in res_a.outputs:
        a, b = res_a.outputs[name], res_b.outputs[name]
        assert a.column_names == b.column_names, name
        for col in a.column_names:
            np.testing.assert_array_equal(
                a.column(col), b.column(col), err_msg=f"{name}:{col}"
            )


# ------------------------------------------------------- expectation helpers
def expect_zero_rows(warm, cold):
    """The contract's full-hit guarantee: nothing reached a user function."""
    assert warm.rows_to_user_fns == 0, warm.node_stats


def expect_fresh_rows(node: str, n: int):
    def check(warm, cold):
        got = warm.node_stats[node]["fresh_rows"]
        assert got == n, f"{node}: expected {n} fresh rows, got {got}"

    return check


def expect_fresh_rows_between(node: str, lo: int, hi: int):
    def check(warm, cold):
        got = warm.node_stats[node]["fresh_rows"]
        assert lo <= got <= hi, f"{node}: expected [{lo}, {hi}] fresh rows, got {got}"

    return check


def _all_of(*checks):
    checks = [c for c in checks if c is not None]

    def check(warm, cold):
        for c in checks:
            c(warm, cold)

    return check


# ------------------------------------------------------------------ the sweep
def _snapshot_ids(catalog) -> Dict[str, str]:
    return {
        t: catalog.current_snapshot(t).snapshot_id for t in catalog.list_tables()
    }


def sweep(tmp_path, setup, factory, edits: List[Edit]) -> List[Tuple[str, object, object]]:
    """Drive the matrix; returns ``[(label, warm_res, cold_res), ...]``.

    ``setup(root)`` builds a workspace and seeds its catalog (it must be
    deterministic: the cold reference calls it again per edit).
    ``factory(**params)`` builds the project for an edit's parameters (it
    must be pure in its params: warm and cold instantiate it separately, so
    the code fingerprints must agree).
    """
    warm = setup(str(tmp_path / "em-warm"))
    history: List[Callable] = []
    # snapshot state after the first N mutations, for travel edits
    snap_ids: Dict[int, Dict[str, str]] = {0: _snapshot_ids(warm.catalog)}
    out = []
    for i, edit in enumerate(edits):
        if edit.mutate is not None:
            edit.mutate(warm.catalog)
            history.append(edit.mutate)
            snap_ids[len(history)] = _snapshot_ids(warm.catalog)
        if edit.travel_to is not None:
            assert edit.travel_to <= len(history), (
                f"{edit.label}: travel_to={edit.travel_to} but only "
                f"{len(history)} mutations have happened"
            )
            pins = snap_ids[edit.travel_to]
            warm_res = warm.run(factory(**edit.params), snapshot_pins=pins)
            cold_history = history[: edit.travel_to]
        else:
            warm_res = warm.run(factory(**edit.params))
            cold_history = list(history)
        # the cold reference: a fresh workspace, the same catalog history
        # (snapshot ids are not reproducible across workspaces, so a travel
        # edit's reference replays only the history up to the pinned point)
        cold = setup(str(tmp_path / f"em-cold-{i}-{edit.label}"))
        for m in cold_history:
            m(cold.catalog)
        cold_res = cold.run(factory(**edit.params))
        assert_outputs_bitwise_equal(warm_res, cold_res)
        assert warm_res.rows_to_user_fns <= cold_res.rows_to_user_fns, (
            f"{edit.label}: warm fed user fns {warm_res.rows_to_user_fns} rows, "
            f"cold only {cold_res.rows_to_user_fns} — the cache made work"
        )
        if edit.expect is not None:
            edit.expect(warm_res, cold_res)
        out.append((edit.label, warm_res, cold_res))
    return out


# ------------------------------------------------------- the canonical matrix
def standard_matrix(
    *,
    base: Dict,
    widen: Dict,
    narrow: Dict,
    beyond: Dict,
    feature_add: Dict,
    feature_remove: Dict,
    code_edit: Dict,
    append: Callable,
    overwrite: Callable,
    expectations: Optional[Dict[str, Callable]] = None,
) -> List[Edit]:
    """The full ISSUE-6 edit matrix as a sequential program for :func:`sweep`.

    Parameter dicts are project-factory kwargs per axis; ``append`` and
    ``overwrite`` are catalog mutations.  ``expectations`` maps edit labels
    to extra ledger checks; ``rerun`` and ``narrow`` always assert the
    zero-recompute guarantee on top of whatever the caller adds.

    Sequence (state accumulates left to right): cold → rerun → widen →
    narrow → beyond-data → feature-add → feature-remove → append →
    overwrite → code-edit → travel (pinned to the post-append snapshot).
    """
    exp = expectations or {}
    return [
        Edit("cold", base, expect=exp.get("cold")),
        Edit("rerun", base, expect=_all_of(expect_zero_rows, exp.get("rerun"))),
        Edit("widen", widen, expect=exp.get("widen")),
        Edit("narrow", narrow, expect=_all_of(expect_zero_rows, exp.get("narrow"))),
        Edit("beyond", beyond, expect=exp.get("beyond")),
        Edit("feature-add", feature_add, expect=exp.get("feature-add")),
        Edit("feature-remove", feature_remove, expect=exp.get("feature-remove")),
        Edit("append", beyond, mutate=append, expect=exp.get("append")),
        Edit("overwrite", beyond, mutate=overwrite, expect=exp.get("overwrite")),
        Edit("code-edit", code_edit, expect=exp.get("code-edit")),
        Edit("travel", beyond, travel_to=1, expect=exp.get("travel")),
    ]
