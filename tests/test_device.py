"""Device-resident cache tier (ISSUE 8): pinning, device UNION assembly,
LRU demotion, spill→device promotion, fallback accounting, and the bitwise
contract against the numpy path — property-checked and swept through the
full edit matrix with a device-enabled warm workspace.
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from edit_matrix import standard_matrix, sweep
from repro.core.cache import DifferentialStore
from repro.core.columnar import ChunkedTable, Table
from repro.core.device import (
    ROW_BLOCK,
    DeviceChunkedTable,
    DeviceTier,
    device_union,
)
from repro.core.device import _pad_rows
from repro.core.intervals import Interval, IntervalSet
from repro.core.spill import ObjectStore, SpillTier
from repro.kernels.fragment_gather import fragment_gather, gather_ref
from repro.pipeline.dsl import Model, Project, model, runtime
from repro.pipeline.executor import Workspace

SCHEMA = {"eventTime": "<i8", "c1": "<f8", "c2": "<f8", "c3": "<i8"}


def events_table(lo, hi, seed=0):
    n = hi - lo
    rng = np.random.default_rng(seed + lo)
    return Table(
        {
            "eventTime": np.arange(lo, hi, dtype=np.int64),
            "c1": rng.standard_normal(n),
            "c2": rng.standard_normal(n),
            "c3": rng.integers(0, 100, n).astype(np.int64),
        }
    )


def jax_feature_project(where="eventTime >= 0 AND eventTime < 800",
                        columns=("c1", "c3"), gain=1.0, scaled_mode="none"):
    """cleaned (jax rowwise) -> scaled (jax): the device tier's consumer
    shape.  ``scaled_mode="none"`` makes the second stage a full-window
    consumer (re-reads every row each run — where the numpy path pays the
    host link); the edit-matrix sweep uses ``"rowwise"`` so its
    zero-recompute expectations hold.  Exactly-rounded elementwise ops only —
    residual recomputes must be bitwise-stable across batch shapes."""
    p = Project("devfeat")
    cols = list(columns)

    @model(project=p, incremental="rowwise")
    @runtime("jax")
    def cleaned(data=Model("ns.raw", columns=cols, filter=where)):
        return {
            k: (jnp.where(v >= 0, v, v * jnp.float32(0.5)) if v.dtype.kind == "f" else v)
            for k, v in data.items()
        }

    @model(project=p, incremental=scaled_mode)
    @runtime("jax")
    def scaled(data=Model("cleaned")):
        return {
            k: (v * jnp.float32(gain) if v.dtype.kind == "f" else v)
            for k, v in data.items()
        }

    return p


# ---------------------------------------------------- device_union: property
@settings(max_examples=30, deadline=None)
@given(
    st.integers(0, 2**31 - 1),
    st.sampled_from(["<f4", "<i8", "<i1"]),
    st.integers(0, 5),
    st.booleans(),
)
def test_device_union_bitwise_equals_numpy_reference(seed, dtype, n_runs, aligned):
    """The bitwise contract across dtypes (f32 / i64 / i8), run counts
    (including the empty-residual and single-fragment shapes), and window
    alignment (aligned → block-run fast path; non-aligned → fallback):
    device_union of padded pins ≡ host np.concatenate then jnp.asarray."""
    rng = np.random.default_rng(seed)
    dt = np.dtype(dtype)
    providers = []
    for _ in range(int(rng.integers(1, 4))):
        rows = int(rng.integers(1, 300))
        if dt.kind == "f":
            host = rng.standard_normal(rows).astype(dt)
        else:
            host = rng.integers(-100, 100, rows).astype(dt)
        providers.append(host)
    runs, host_parts = [], []
    dev = [{"x": _pad_rows(jnp.asarray(h))} for h in providers]
    for _ in range(n_runs):
        i = int(rng.integers(len(providers)))
        n = len(providers[i])
        lo = int(rng.integers(0, n + 1))
        hi = int(rng.integers(lo, n + 1))
        if aligned:
            lo, hi = (lo // ROW_BLOCK) * ROW_BLOCK, (hi // ROW_BLOCK) * ROW_BLOCK
        runs.append((dev[i], lo, hi))
        host_parts.append(providers[i][lo:hi])
    ledger = {}
    got = device_union(runs, ["x"], interpret=True, ledger=ledger)
    if not runs:
        assert got == {}
        return
    expected = np.asarray(  # x32 downcast commutes with the concat
        jnp.asarray(np.concatenate(host_parts or [providers[0][0:0]]))
    )
    np.testing.assert_array_equal(np.asarray(got["x"]), expected)


def test_device_union_single_fragment_is_a_slice():
    """One run from one provider: a gather would be the identity, so the
    union is a device slice — no kernel call counted either way."""
    host = np.arange(64, dtype=np.float32)
    ledger = {}
    got = device_union(
        [({"x": _pad_rows(jnp.asarray(host))}, 8, 40)], ["x"],
        interpret=True, ledger=ledger,
    )
    np.testing.assert_array_equal(np.asarray(got["x"]), host[8:40])
    assert "gather_fast" not in ledger and "gather_fallbacks" not in ledger
    assert ledger["device_unions"] == 1


def test_device_union_multi_interval_hits_fast_path():
    """Two aligned runs of ONE provider become a single block-run
    fragment_gather on the tiled fast path."""
    host = np.arange(512, dtype=np.float32)
    prov = {"x": _pad_rows(jnp.asarray(host))}
    ledger = {}
    got = device_union(
        [(prov, 0, 128), (prov, 256, 512)], ["x"], interpret=True, ledger=ledger
    )
    np.testing.assert_array_equal(
        np.asarray(got["x"]), np.concatenate([host[0:128], host[256:512]])
    )
    assert ledger["gather_fast"] == 1
    assert "gather_fallbacks" not in ledger


def test_device_union_non_aligned_counts_fallback_downgrade():
    """Off-alignment runs still serve (RB=1-grade kernel or XLA take) but
    the silent downgrade is counted, not hidden."""
    host = np.arange(512, dtype=np.float32)
    prov = {"x": _pad_rows(jnp.asarray(host))}
    ledger = {}
    got = device_union(
        [(prov, 3, 130), (prov, 259, 500)], ["x"], interpret=True, ledger=ledger
    )
    np.testing.assert_array_equal(
        np.asarray(got["x"]), np.concatenate([host[3:130], host[259:500]])
    )
    assert ledger["gather_fallbacks"] == 1
    assert "gather_fast" not in ledger


def test_device_union_empty_runs_yield_empty_columns():
    prov = {"x": _pad_rows(jnp.asarray(np.arange(16, dtype=np.float32)))}
    got = device_union([(prov, 4, 4), (prov, 9, 9)], ["x"], interpret=True)
    assert np.asarray(got["x"]).shape == (0,)


# ------------------------------------------------- fragment_gather regressions
def test_fragment_gather_tail_not_padded_into_output():
    """R not a multiple of row_block: the tile-padded tail must never leak
    zero rows into the output (the _pad_axis regression)."""
    src = jnp.asarray(np.random.default_rng(0).standard_normal((64, 4)).astype(np.float32))
    idx = np.arange(13, dtype=np.int32)  # 13 % 8 != 0
    out = fragment_gather(src, idx, row_block=8, interpret=True)
    assert out.shape == (13, 4)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(gather_ref(src, jnp.asarray(idx))))


def test_fragment_gather_rejects_out_of_range_indices():
    src = jnp.asarray(np.zeros((10, 4), np.float32))
    with pytest.raises(IndexError):
        fragment_gather(src, np.array([0, 10], np.int32), row_block=8, interpret=True)


# ----------------------------------------------------- ChunkedTable column memo
def test_chunked_table_column_memoized_and_frozen():
    chunks = [events_table(0, 100), events_table(100, 200)]
    ct = ChunkedTable(chunks)
    a = ct.column("c1")
    assert ct.column("c1") is a, "second access must hit the memo"
    with pytest.raises(ValueError):
        a[0] = 99.0  # memoized arrays are read-only: aliasing is safe


def test_chunked_table_single_chunk_column_is_zero_copy():
    t = events_table(0, 50)
    ct = ChunkedTable([t])
    assert np.shares_memory(ct.column("c1"), t.column("c1"))


# -------------------------------------------------------------- DeviceTier unit
class _Elem:
    _next = iter(range(10_000, 20_000))

    def __init__(self, data):
        self.elem_id = next(self._next)
        self.data = data


def test_device_tier_pin_hit_and_lru_eviction():
    col = np.arange(256, dtype=np.float64)
    elem_bytes = _pad_rows(jnp.asarray(col)).nbytes
    tier = DeviceTier(max_bytes=2 * elem_bytes, interpret=True)
    elems = [_Elem(Table({"x": col + i})) for i in range(3)]
    for e in elems[:2]:
        assert tier.pin(e, "x") is not None
    assert tier.pin(elems[0], "x") is not None  # refresh elems[0]'s LRU slot
    assert tier.stats()["device_hits"] == 1
    assert tier.pin(elems[2], "x") is not None  # over budget → evict elems[1]
    assert tier.get(elems[1].elem_id, "x") is None
    assert tier.get(elems[0].elem_id, "x") is not None
    assert tier.stats()["device_evictions"] == 1
    assert tier.nbytes <= 2 * elem_bytes


def test_device_tier_unsupported_dtype_falls_back():
    tier = DeviceTier(interpret=True)
    e = _Elem(Table({"s": np.array(["a", "b"], dtype="<U1")}))
    assert tier.pin(e, "s") is None
    assert tier.pin_columns(e, ["s"]) is None
    assert len(tier) == 0


def test_device_tier_drop_element_forgets_all_pins():
    tier = DeviceTier(interpret=True)
    e = _Elem(events_table(0, 32))
    assert tier.pin_columns(e, ["c1", "c3"]) is not None
    assert len(tier) == 2
    tier.drop_element(e.elem_id)
    assert len(tier) == 0
    assert tier.get(e.elem_id, "c1") is None


# ----------------------------------------- store integration: merge replication
def _insert(store, sig, lo, hi, seed=0):
    return store.insert_window(
        signature=sig, table="t", sort_key="k",
        window=IntervalSet([Interval(lo, hi)]),
        data=Table({
            "k": np.arange(lo, hi, dtype=np.int64),
            "x": np.random.default_rng(seed + lo).standard_normal(hi - lo),
        }),
    )


def test_merge_replicates_pins_device_to_device():
    """Merging two pinned elements rebuilds the merged pin by device→device
    gather: zero new H2D, bytes_replicated > 0, parents dropped."""
    tier = DeviceTier(interpret=True)
    store = DifferentialStore(device=tier)
    a = _insert(store, "s", 0, 64)
    tier.pin_columns(a, ["k", "x"])
    h2d_before = tier.stats()["bytes_h2d"]
    plan = store.plan_window(
        "s", IntervalSet([Interval(0, 128)]), (), lambda w: w.measure(),
        device_consumer=True,
    )
    assert plan.residual.to_pairs() == ((64, 128),)
    fresh = Table({
        "k": np.arange(64, 128, dtype=np.int64),
        "x": np.random.default_rng(1).standard_normal(64),
    })
    dev_arrays = {c: jnp.asarray(fresh.column(c)) for c in fresh.column_names}
    store.insert_window(
        signature="s", table="t", sort_key="k",
        window=IntervalSet([Interval(64, 128)]), data=fresh,
        device_arrays=dev_arrays,
    )
    (merged,) = store.elements("s")
    assert merged.window.to_pairs() == ((0, 128),)
    stats = tier.stats()
    assert stats["bytes_h2d"] == h2d_before, "merge must not upload"
    assert stats["bytes_replicated"] > 0
    arrays = tier.element_arrays(merged, ["k", "x"])
    assert arrays is not None
    np.testing.assert_array_equal(
        np.asarray(arrays["x"][: merged.data.num_rows]),
        np.asarray(jnp.asarray(merged.data.column("x"))),
    )


def test_spill_promotion_goes_straight_to_device(tmp_path):
    """A demoted element planned for a jax consumer promotes mmap → H2D
    once: resident on device, plan charged with the upload."""
    tier = DeviceTier(interpret=True)
    spill = SpillTier(ObjectStore(str(tmp_path / "obj")))
    store = DifferentialStore(spill=spill, device=tier)
    _insert(store, "s", 0, 64)
    store.demote_all()
    assert store.nbytes == 0
    plan = store.plan_window(
        "s", IntervalSet([Interval(0, 64)]), (), lambda w: w.measure(),
        device_consumer=True,
    )
    assert plan.hits
    assert spill.device_promotions == 1
    assert plan.bytes_h2d > 0
    assert tier.get(plan.hits[0].element.elem_id, "x") is not None


def test_shared_store_stats_carry_device_ledger(tmp_path):
    from repro.service import SharedStore

    plain = SharedStore()
    keys = ("device_nbytes", "device_entries", "bytes_h2d", "device_hits",
            "device_evictions", "device_pins", "bytes_replicated")
    s = plain.stats()
    assert all(s[k] == 0 for k in keys)

    tiered = SharedStore(device=DeviceTier(interpret=True))
    _insert(tiered, "s", 0, 32)
    tiered.device.pin_columns(tiered.elements("s")[0], ["k", "x"])
    s = tiered.stats()
    assert s["device_entries"] == 2 and s["bytes_h2d"] > 0


# ------------------------------------------------- executor: end-to-end serving
def _dev_workspace(root, device=True):
    ws = Workspace(
        root, rows_per_fragment=128,
        device=DeviceTier(interpret=True) if device else None,
    )
    ws.catalog.create_table("ns", "raw", SCHEMA, "eventTime")
    ws.catalog.append("ns.raw", events_table(0, 1024))
    return ws


def _w(lo, hi):
    return f"(eventTime >= {lo} AND eventTime < {hi})"


def test_warm_run_serves_from_device_and_counts_hits(tmp_path):
    ws = _dev_workspace(str(tmp_path / "dev"))
    ref = _dev_workspace(str(tmp_path / "ref"), device=False)
    for where in (_w(0, 1024), _w(0, 1024)):
        dres = ws.run(jax_feature_project(where))
        rres = ref.run(jax_feature_project(where))
        for name, table in dres.outputs.items():
            for col in table.column_names:
                np.testing.assert_array_equal(
                    np.asarray(table.column(col)),
                    np.asarray(rres.outputs[name].column(col)),
                    err_msg=f"{name}:{col}",
                )
    assert dres.bytes_h2d == 0, "warm rerun must not touch the host link"
    assert dres.device_hits > 0
    assert rres.bytes_h2d >= 0  # numpy path counts its uploads too
    assert ws.device.stats()["device_entries"] > 0


def test_multi_interval_window_takes_gather_fast_path(tmp_path):
    """An OR-window served from two intervals of one merged element is a
    genuine multi-run fragment_gather — aligned bounds hit the tiled fast
    path and the ledger says so."""
    ws = _dev_workspace(str(tmp_path / "dev"))
    ws.run(jax_feature_project(_w(0, 1024)))
    res = ws.run(jax_feature_project(f"{_w(0, 256)} OR {_w(512, 1024)}"))
    assert res.gather_fast >= 1
    assert res.bytes_h2d == 0


def test_non_aligned_window_counts_fallback_downgrade(tmp_path):
    ws = _dev_workspace(str(tmp_path / "dev"))
    ws.run(jax_feature_project(_w(0, 1024)))
    res = ws.run(jax_feature_project(f"{_w(3, 259)} OR {_w(515, 1019)}"))
    assert res.gather_fallbacks >= 1
    ref = _dev_workspace(str(tmp_path / "ref"), device=False)
    ref.run(jax_feature_project(_w(0, 1024)))
    rres = ref.run(jax_feature_project(f"{_w(3, 259)} OR {_w(515, 1019)}"))
    for name, table in res.outputs.items():
        for col in table.column_names:
            np.testing.assert_array_equal(
                np.asarray(table.column(col)),
                np.asarray(rres.outputs[name].column(col)),
            )


def test_device_chunked_table_select_keeps_device_columns():
    t = events_table(0, 64)
    dct = DeviceChunkedTable([t], {"c1": jnp.asarray(t.column("c1"))})
    sel = dct.select(["c1", "c3"])
    assert isinstance(sel, DeviceChunkedTable)
    assert set(sel.device_columns) == {"c1"}


# ----------------------------------------------------- the edit-matrix contract
def test_edit_matrix_device_warm_vs_numpy_cold(tmp_path):
    """The strictest gate: a device-enabled warm workspace driven through the
    full ISSUE-6 edit matrix must stay bitwise-equal to numpy-path cold
    replays on EVERY edit (the cold setups get no device tier)."""

    def setup(root):
        # sweep() uses one warm root and fresh cold roots per edit: give the
        # warm workspace the tier, the cold references the plain numpy path
        return _dev_workspace(root, device=root.endswith("em-warm"))

    def factory(hi=499, columns=("c1", "c3"), gain=1.0):
        return jax_feature_project(
            _w(0, hi + 1), columns=columns, gain=gain, scaled_mode="rowwise"
        )

    append = lambda c: c.append("ns.raw", events_table(1024, 1124, seed=9))
    overwrite = lambda c: c.overwrite_range(
        "ns.raw", 128, 256, events_table(128, 256, seed=77)
    )
    edits = standard_matrix(
        base=dict(hi=499),
        widen=dict(hi=1023),
        narrow=dict(hi=299),
        beyond=dict(hi=4999),
        feature_add=dict(hi=4999, columns=("c1", "c2", "c3")),
        feature_remove=dict(hi=4999),
        code_edit=dict(hi=4999, gain=2.0),
        append=append,
        overwrite=overwrite,
    )
    results = sweep(tmp_path, setup, factory, edits)
    assert any(w.device_hits > 0 for _l, w, _c in results[1:]), (
        "the warm workspace never served from the device tier"
    )


# ----------------------------------------------------------- bench8 acceptance
def test_bench8_acceptance():
    from benchmarks import bench8_device as b8

    # smallest scale where the fixed-size append residual (one fragment)
    # doesn't dominate the device path's warm uploads
    result = b8.run(rows=16384)
    assert result["bitwise_equal"]
    assert result["warm"]["h2d_ratio"] >= 5
    assert result["warm"]["gather_fast"] >= 1
    assert result["roofline"]["modeled_speedup"] > 1
