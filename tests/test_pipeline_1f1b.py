"""1F1B pipeline-parallel training: numerics vs the sequential layer stack,
activation-memory bounds vs GPipe, and composition with ``train_loop``.

The multi-device parts run in a subprocess because the pipeline mesh needs
``XLA_FLAGS=--xla_force_host_platform_device_count`` set before jax
initializes (same pattern as ``test_dist_extras``); CI also invokes this
file directly on a multi-device CPU mesh.
"""

import os
import subprocess
import sys
import textwrap

import pytest

from repro.dist.pipeline import schedule_report

_BODY = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.dist.pipeline import (
        _pipeline_train_program, pipeline_value_and_grad,
        stack_stage_params, unstack_stage_params,
    )
    from repro.train.loop import (
        make_pipeline_init_state, make_pipeline_train_step, train_loop,
    )
    from repro.train.optimizer import OptimizerConfig, make_optimizer
    from repro.train.state import TrainState

    S_STAGES, L, D = 4, 8, 16
    M, MB, SEQ = 6, 2, 4

    Ws = jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * (D ** -0.5)

    def layer_fn(x, lp):
        return jnp.tanh(x @ lp["W"])

    def loss_fn(y, aux):
        d = (y - aux["tgt"]).astype(jnp.float32)
        return jnp.sum(d * d), jnp.float32(d.size)

    x = jax.random.normal(jax.random.PRNGKey(1), (M, MB, SEQ, D))
    tgt = jax.random.normal(jax.random.PRNGKey(2), (M, MB, SEQ, D))

    # ---- sequential reference: same microbatch-ordered f32 accumulation
    def seq_loss(p, xm, tm):
        def body(c, W):
            return jnp.tanh(c @ W), None
        out, _ = jax.lax.scan(body, xm, p)
        d = (out - tm).astype(jnp.float32)
        return jnp.sum(d * d)

    vg = jax.value_and_grad(seq_loss)
    g_ref = jnp.zeros_like(Ws)
    l_ref = jnp.zeros((), jnp.float32)
    for m in range(M):
        l, g = vg(Ws, x[m], tgt[m])
        l_ref, g_ref = l_ref + l, g_ref + g.astype(jnp.float32)

    mesh = Mesh(np.array(jax.devices()[:4]), ("pp",))
    staged = jax.device_put(
        stack_stage_params({"W": Ws}, S_STAGES), NamedSharding(mesh, P("pp"))
    )

    # ---- loss + grads equal the sequential stack, for BOTH schedules
    for sched in ("1f1b", "gpipe"):
        (loss, count), grads = pipeline_value_and_grad(
            mesh, layer_fn, loss_fn, staged, x, {"tgt": tgt}, schedule=sched
        )
        np.testing.assert_allclose(float(loss), float(l_ref), rtol=1e-6)
        assert float(count) == M * MB * SEQ * D
        np.testing.assert_allclose(
            np.asarray(unstack_stage_params(grads)["W"]), np.asarray(g_ref),
            rtol=1e-5, atol=1e-7,
        )
    print("NUMERICS_OK")

    # ---- M < S degenerate case still correct
    (l2, _), _ = pipeline_value_and_grad(
        mesh, layer_fn, loss_fn, staged, x[:2], {"tgt": tgt[:2]}
    )
    want2 = sum(float(vg(Ws, x[m], tgt[m])[0]) for m in range(2))
    np.testing.assert_allclose(float(l2), want2, rtol=1e-6)
    print("SMALL_M_OK")

    # ---- 1F1B's activation stash is bounded by in-flight microbatches:
    # compiled temp memory must not exceed GPipe's (M-slot stash) program
    MEM_M = 12
    xm = jax.random.normal(jax.random.PRNGKey(3), (MEM_M, MB, SEQ, D))
    tm = jax.random.normal(jax.random.PRNGKey(4), (MEM_M, MB, SEQ, D))
    temps = {}
    for sched in ("1f1b", "gpipe"):
        prog = _pipeline_train_program(mesh, layer_fn, loss_fn, "pp", sched)
        mem = prog.lower(staged, xm, {"tgt": tm}).compile().memory_analysis()
        temps[sched] = int(mem.temp_size_in_bytes)
    print("temps", temps)
    assert temps["1f1b"] < temps["gpipe"], temps
    print("MEMORY_OK")

    # ---- make_pipeline_train_step composes with train_loop and matches a
    # sequential train step exactly (params after N optimizer steps)
    B = M * MB  # global batch
    opt = OptimizerConfig(kind="adamw", peak_lr=1e-2, warmup_steps=2)
    state = make_pipeline_init_state(opt)(staged)
    step = make_pipeline_train_step(
        mesh, layer_fn, loss_fn, opt, microbatches=M
    )

    def batch_stream(seed, n):
        rng = np.random.default_rng(seed)
        for _ in range(n):
            yield {
                "inputs": jnp.asarray(
                    rng.standard_normal((B, SEQ, D)), jnp.float32
                ),
                "aux": {"tgt": jnp.asarray(
                    rng.standard_normal((B, SEQ, D)), jnp.float32
                )},
            }

    N_STEPS = 6
    state, hist = train_loop(step, state, batch_stream(7, N_STEPS), N_STEPS)
    assert int(state.step) == N_STEPS
    assert all(np.isfinite(h["loss"]) for h in hist)

    _, opt_update = make_optimizer(opt)
    ref = TrainState(
        params={"W": Ws},
        opt=make_optimizer(opt)[0]({"W": Ws}),
        step=jnp.zeros((), jnp.int32),
    )

    def seq_step(st, batch):
        xs = batch["inputs"].reshape((M, MB, SEQ, D))
        ts = batch["aux"]["tgt"].reshape((M, MB, SEQ, D))
        vgm = jax.value_and_grad(
            lambda p, xm, tm: seq_loss(p["W"], xm, tm)
        )
        g = {"W": jnp.zeros(Ws.shape, jnp.float32)}
        nll = jnp.zeros((), jnp.float32)
        cnt = jnp.float32(M * MB * SEQ * D)
        for m in range(M):
            l, gm = vgm(st.params, xs[m], ts[m])
            g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g, gm)
            nll = nll + l
        g = jax.tree.map(lambda a: a / cnt, g)
        newp, newo, _ = opt_update(g, st.opt, st.params, st.step)
        return TrainState(params=newp, opt=newo, step=st.step + 1)

    for batch in batch_stream(7, N_STEPS):
        ref = seq_step(ref, batch)

    np.testing.assert_allclose(
        np.asarray(unstack_stage_params(state.params)["W"]),
        np.asarray(ref.params["W"]),
        rtol=1e-5, atol=1e-6,
    )
    print("TRAIN_STEP_OK")
    """
)


def test_1f1b_subprocess_suite():
    """One subprocess run covers numerics, M<S, compiled memory, train step."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", _BODY],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(__file__)), timeout=600,
    )
    for want in ("NUMERICS_OK", "SMALL_M_OK", "MEMORY_OK", "TRAIN_STEP_OK"):
        assert want in out.stdout, (out.stdout[-2000:], out.stderr[-3000:])


# ----------------------------------------------------- analytic schedule math
def test_schedule_report_memory_and_bubble():
    r = schedule_report(n_stages=4, n_micro=16, microbatch_bytes=1 << 20)
    # 1F1B stashes only in-flight microbatches: S slots vs GPipe's M
    assert r["peak_stash_micro_1f1b"] == 4
    assert r["peak_stash_micro_gpipe"] == 16
    assert r["peak_stash_bytes_1f1b"] <= r["peak_stash_bytes_gpipe"]
    # non-interleaved 1F1B keeps GPipe's bubble; interleaving shrinks it
    assert r["bubble_1f1b"] == pytest.approx(3 / 19)
    r2 = schedule_report(4, 16, 1 << 20, n_virtual=2)
    assert r2["bubble_1f1b_interleaved"] < r["bubble_1f1b"]


def test_schedule_report_degenerate_cases():
    r = schedule_report(n_stages=1, n_micro=4, microbatch_bytes=10)
    assert r["bubble_1f1b"] == 0.0
    assert r["peak_stash_micro_1f1b"] == 1
    r = schedule_report(n_stages=8, n_micro=2, microbatch_bytes=10)
    assert r["peak_stash_micro_1f1b"] == 2  # M < S: bounded by M
    with pytest.raises(ValueError):
        schedule_report(0, 4, 10)
