"""``incremental="keyed"`` (ISSUE 6 tentpole): per-key-group aggregations
cached at key-group granularity.  An append/overwrite touching a handful of
key groups re-aggregates ONLY those groups — located through fragment
key-min/max stats — and the output UNIONs recomputed groups with cached
ones, bitwise-identical to a cold run.

Soundness rests on key-range windows never splitting a key group (groups
live at single key points; every window boundary the system produces is a
key-range bound; residual inputs re-read by key range pick up ALL rows of a
touched group, including rows in untouched neighbouring fragments), so the
full edit matrix from ``edit_matrix.py`` must hold verbatim — plus a
threaded stress on one SharedStore and the BENCH_6 acceptance gate.
"""

import tempfile
import threading

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from edit_matrix import (
    assert_outputs_bitwise_equal,
    expect_fresh_rows,
    expect_fresh_rows_between,
    expect_zero_rows,
    standard_matrix,
    sweep,
)
from repro.core.columnar import Table
from repro.pipeline import DagError, Model, Project, Workspace, build_dag, model, runtime
from repro.service import PipelineService

SCHEMA = {"user": "<i8", "amount": "<f8", "flag": "<i8"}


def activity_table(lo_u, hi_u, per_user=5, seed=0):
    """``per_user`` rows for each user key in [lo_u, hi_u), sorted by user."""
    n = (hi_u - lo_u) * per_user
    rng = np.random.default_rng(seed + lo_u)
    return Table(
        {
            "user": np.repeat(np.arange(lo_u, hi_u, dtype=np.int64), per_user),
            "amount": rng.standard_normal(n),
            "flag": rng.integers(0, 4, n).astype(np.int64),
        }
    )


def make_workspace(root, users=200):
    ws = Workspace(root, rows_per_fragment=128)
    ws.catalog.create_table("ns", "act", SCHEMA, "user")
    ws.catalog.append("ns.act", activity_table(0, users))
    return ws


def _aggregate(users, amounts, flags=None):
    """Per-user sum/count (and max flag when given) via reduceat — rows of a
    group are contiguous because the input arrives sorted by the key."""
    uniq, starts = np.unique(users, return_index=True)
    if uniq.size == 0:
        out = {
            "user": uniq,
            "total": np.zeros(0, np.float64),
            "n": np.zeros(0, np.int64),
        }
        if flags is not None:
            out["maxflag"] = np.zeros(0, np.int64)
        return out
    out = {
        "user": uniq,
        "total": np.add.reduceat(amounts, starts),
        "n": np.diff(np.append(starts, users.size)).astype(np.int64),
    }
    if flags is not None:
        out["maxflag"] = np.maximum.reduceat(flags, starts)
    return out


def keyed_project(hi=99, columns=("amount",), gain=1.0):
    """peruser (keyed aggregation) -> scored (rowwise map over the groups),
    parameterized along the same edit axes as the rowwise chain."""
    p = Project("keyed")
    cols = list(columns)

    @model(project=p, incremental="keyed")
    @runtime("numpy")
    def peruser(data=Model("ns.act", columns=cols, filter=f"user BETWEEN 0 AND {hi}")):
        return _aggregate(
            np.asarray(data.column("user")),
            np.asarray(data.column("amount"), np.float64),
            flags=(
                np.asarray(data.column("flag"))
                if "flag" in data.column_names
                else None
            ),
        )

    @model(project=p, incremental="rowwise")
    @runtime("numpy")
    def scored(data=Model("peruser")):
        out = {n: data.column(n) for n in data.column_names}
        out["score"] = gain * np.asarray(data.column("total"), np.float64)
        return out

    return p


# ------------------------------------------------------------- DSL validation
def test_keyed_requires_exactly_one_input():
    p = Project("badjoin")

    @model(project=p, incremental="keyed")
    def agg(
        a=Model("ns.x", columns=["c1"]),
        b=Model("ns.y", columns=["c1"]),
    ):
        return a

    with pytest.raises(DagError, match="exactly one"):
        build_dag(p)


def test_keyed_requires_windowed_upstream():
    p = Project("badup")

    @model(project=p)  # default: none — no window to slice residuals from
    def prep(data=Model("ns.act", columns=["amount"])):
        return data

    @model(project=p, incremental="keyed")
    def agg(data=Model("prep")):
        return data

    with pytest.raises(DagError, match="windowed"):
        build_dag(p)


# --------------------------------------------------------- contract violations
def test_keyed_fn_must_return_sort_key(tmp_path):
    p = Project("nokey")

    @model(project=p, incremental="keyed")
    def agg(data=Model("ns.act", columns=["amount"], filter="user BETWEEN 0 AND 99")):
        u = np.asarray(data.column("user"))
        uniq, starts = np.unique(u, return_index=True)
        return {"total": np.add.reduceat(np.asarray(data.column("amount")), starts)}

    ws = make_workspace(str(tmp_path / "lake"))
    with pytest.raises(ValueError, match="keyed aggregation must return the sort key"):
        ws.run(p)


def test_keyed_fn_creating_rows_rejected(tmp_path):
    p = Project("morerows")

    @model(project=p, incremental="keyed")
    def agg(data=Model("ns.act", columns=["amount"], filter="user BETWEEN 0 AND 99")):
        u = np.asarray(data.column("user"))
        a = np.asarray(data.column("amount"))
        return {"user": np.concatenate([u, u]), "amount": np.concatenate([a, a])}

    ws = make_workspace(str(tmp_path / "lake"))
    with pytest.raises(ValueError, match="must not create rows"):
        ws.run(p)


def test_keyed_fn_inventing_keys_rejected(tmp_path):
    """An output key absent from the input would land in a window this
    residual does not own — cached neighbours would then disagree with a
    cold run, so it must be rejected up front."""
    p = Project("newkeys")

    @model(project=p, incremental="keyed")
    def agg(data=Model("ns.act", columns=["amount"], filter="user BETWEEN 0 AND 99")):
        out = _aggregate(
            np.asarray(data.column("user")),
            np.asarray(data.column("amount"), np.float64),
        )
        out["user"] = out["user"] + 100_000  # keys the input never held
        return out

    ws = make_workspace(str(tmp_path / "lake"))
    with pytest.raises(ValueError, match="drawn from the input keys"):
        ws.run(p)


# ------------------------------------------------------------ the edit matrix
def test_edit_matrix_keyed(tmp_path):
    """The full ISSUE-6 edit matrix for the keyed contract: 200 users x 5
    rows, 128-row fragments (so key groups span fragment boundaries), one
    warm workspace through every edit axis, bitwise-equal to cold."""
    # 10 extra rows for EXISTING users [50, 60): touched groups re-aggregate
    # whole (old rows + new), everything else serves from cache
    append = lambda c: c.append("ns.act", activity_table(50, 60, per_user=1, seed=5))
    overwrite = lambda c: c.overwrite_range(
        "ns.act", 20, 30, activity_table(20, 30, per_user=5, seed=77)
    )

    def expect_feature_add(warm, cold):
        assert warm.rows_to_user_fns > 0
        assert "maxflag" in warm.outputs["scored"].column_names

    def expect_code_edit(warm, cold):
        assert warm.node_stats["peruser"]["fresh_rows"] == 0
        assert warm.node_stats["scored"]["fresh_rows"] > 0

    edits = standard_matrix(
        base=dict(hi=99),
        widen=dict(hi=199),
        narrow=dict(hi=49),
        beyond=dict(hi=999),
        feature_add=dict(hi=999, columns=("amount", "flag")),
        feature_remove=dict(hi=999),
        code_edit=dict(hi=999, gain=2.0),
        append=append,
        overwrite=overwrite,
        expectations={
            # newly-exposed groups [100, 200): 100 users x 5 rows
            "widen": expect_fresh_rows("peruser", 500),
            # residual [200, 1000) holds no rows
            "beyond": expect_fresh_rows("peruser", 0),
            "feature-add": expect_feature_add,
            # dropping `flag` flips the signature back to a fully-covered one
            "feature-remove": expect_zero_rows,
            # groups [50, 60) whole: 10 users x (5 old + 1 appended) rows
            "append": expect_fresh_rows("peruser", 60),
            # overwritten keys [20, 30) touch 2 fragments whose key stats
            # span [0, 52): at most those groups re-aggregate
            "overwrite": expect_fresh_rows_between("peruser", 50, 320),
            "code-edit": expect_code_edit,
        },
    )
    sweep(tmp_path, make_workspace, keyed_project, edits)


def test_group_spanning_fragment_boundary_reaggregates_whole(tmp_path):
    """User 25's rows straddle the 128-row fragment boundary (rows 125..129).
    Appending more rows for that ONE user must re-aggregate the whole group —
    including its rows in the untouched neighbour fragment — and nothing
    else: the fragment key stats pin window [25, 26) and the residual
    re-reads by key range, not by fragment."""
    ws = make_workspace(str(tmp_path / "warm"))
    ws.run(keyed_project(hi=199))

    extra = Table(
        {
            "user": np.full(3, 25, dtype=np.int64),
            "amount": np.array([1.5, -2.25, 0.75]),
            "flag": np.array([3, 0, 1], dtype=np.int64),
        }
    )
    ws.catalog.append("ns.act", extra)
    res = ws.run(keyed_project(hi=199))
    # the whole group: 5 original rows (3 in fragment 0, 2 in fragment 1)
    # plus the 3 appended ones — and no other group
    assert res.node_stats["peruser"]["fresh_rows"] == 8

    cold = make_workspace(str(tmp_path / "cold"))
    cold.catalog.append("ns.act", extra)
    assert_outputs_bitwise_equal(res, cold.run(keyed_project(hi=199)))


# ------------------------------------------------- property: random edit pairs
@settings(max_examples=8, deadline=None)
@given(
    st.integers(min_value=0, max_value=199),
    st.integers(min_value=1, max_value=40),
    st.integers(min_value=0, max_value=199),
    st.integers(min_value=1, max_value=40),
    st.integers(min_value=0, max_value=10_000),
)
def test_keyed_random_append_overwrite_property(lo_a, w_a, lo_o, w_o, seed):
    """Warm == cold bitwise for ARBITRARY (append range, overwrite range)
    pairs — including overlapping ones — and the warm run never feeds user
    fns more rows than the cold run."""
    hi_a = min(lo_a + w_a, 200)
    hi_o = min(lo_o + w_o, 200)
    ap = lambda c: c.append("ns.act", activity_table(lo_a, hi_a, per_user=2, seed=seed))
    ow = lambda c: c.overwrite_range(
        "ns.act", lo_o, hi_o, activity_table(lo_o, hi_o, per_user=5, seed=seed + 1)
    )
    with tempfile.TemporaryDirectory() as tmp:
        warm = make_workspace(tmp + "/warm")
        warm.run(keyed_project(hi=199))
        ap(warm.catalog)
        ow(warm.catalog)
        warm_res = warm.run(keyed_project(hi=199))

        cold = make_workspace(tmp + "/cold")
        ap(cold.catalog)
        ow(cold.catalog)
        cold_res = cold.run(keyed_project(hi=199))

    assert_outputs_bitwise_equal(warm_res, cold_res)
    assert warm_res.rows_to_user_fns <= cold_res.rows_to_user_fns


# ------------------------------------------------------------ threaded stress
def slow_keyed_project(hi, delay=0.2):
    """Same chain as keyed_project but each stage sleeps, so concurrent runs
    reliably overlap in their residual computations."""
    import time

    p = Project("keyedstress")

    @model(project=p, incremental="keyed")
    @runtime("numpy")
    def peruser(data=Model("ns.act", columns=["amount"], filter=f"user BETWEEN 0 AND {hi}")):
        time.sleep(delay)
        return _aggregate(
            np.asarray(data.column("user")),
            np.asarray(data.column("amount"), np.float64),
        )

    @model(project=p, incremental="rowwise")
    @runtime("numpy")
    def scored(data=Model("peruser")):
        time.sleep(delay)
        out = {n: data.column(n) for n in data.column_names}
        out["score"] = np.asarray(data.column("total"), np.float64) / np.maximum(
            np.asarray(data.column("n"), np.float64), 1.0
        )
        return out

    return p


def test_threaded_keyed_stress_on_shared_store(tmp_path):
    """Concurrent identical keyed runs + appends touching OVERLAPPING key
    groups + budget-forced demotions, all on one SharedStore: per wave the
    residual key groups are computed exactly once across all runs (losers
    coalesce on the claim), every output is bitwise-equal to a cold replay,
    and later waves re-aggregate only the touched groups."""
    seed_users = 160
    with PipelineService(
        str(tmp_path / "svc"),
        workers=3,
        rows_per_fragment=128,
        model_cache_bytes=6_000,  # below the two model elements: demotions
        scan_cache_bytes=60_000,
        spill=True,  # evicted windows must STILL serve (exactly-once holds)
    ) as svc:
        svc.catalog.create_table("ns", "act", SCHEMA, "user")
        svc.catalog.append("ns.act", activity_table(0, seed_users))

        stop = threading.Event()

        def far_appender():
            # rows beyond every window, racing the runs: commits churn the
            # catalog without touching in-window groups
            session = svc.session("far-writer")
            lo = 500
            while not stop.is_set():
                session.append("ns.act", activity_table(lo, lo + 8, per_user=2, seed=3))
                lo += 8

        wt = threading.Thread(target=far_appender)
        wt.start()

        # wave mutations append 1 row per user over OVERLAPPING ranges, so
        # groups [50, 60) are touched twice and grow wave over wave
        waves = [None, (40, 60), (50, 70)]
        expected_rows = [
            seed_users * 5 + seed_users,  # cold: every row through both stages
            20 * 5 + 20 * 1 + 20,  # groups [40,60): 6 rows each + scored
            10 * 7 + 10 * 6 + 20,  # [50,60): 7 rows, [60,70): 6 + scored
        ]
        history = []
        results = []  # (wave, handles)
        try:
            for wave, touch in enumerate(waves):
                if touch is not None:
                    lo_u, hi_u = touch
                    mut = (
                        lambda lo_u=lo_u, hi_u=hi_u, s=101 + wave: lambda c: c.append(
                            "ns.act", activity_table(lo_u, hi_u, per_user=1, seed=s)
                        )
                    )()
                    # commit-retry: the far appender is racing this commit
                    svc.session("writer").append(
                        "ns.act", activity_table(lo_u, hi_u, per_user=1, seed=101 + wave)
                    )
                    history.append(mut)
                # all tenants of a wave pin the SAME snapshot (the far
                # appender keeps moving the head): identical claim tokens,
                # so concurrent residuals coalesce
                snap = svc.catalog.current_snapshot("ns.act").snapshot_id
                tenants = [f"w{wave}-{t}" for t in ("alice", "bob", "carol")]
                for t in tenants:
                    svc.session(t).pin("ns.act", snap)
                project = slow_keyed_project(hi=seed_users - 1)
                handles = [svc.submit(t, project) for t in tenants]
                svc.drain(120)
                for h in handles:
                    assert h.state == "DONE", h.error
                rows = [h.result.rows_to_user_fns for h in handles]
                # exactly-once: summed over ALL concurrent runs, the wave's
                # residual groups were computed a single time
                assert sum(rows) == expected_rows[wave], (wave, rows)
                results.append((wave, handles))
            assert svc.model_store.demotions > 0, "budget must actually bite"
            assert svc.model_store.coalesced_waits >= 1, (
                "losers must subscribe, not recompute"
            )
        finally:
            stop.set()
            wt.join()

    # cold references: replay ONLY the group-touching appends (the far
    # appender's rows never enter the window, so outputs are unaffected)
    for wave, handles in results:
        cold = make_workspace(str(tmp_path / f"cold-{wave}"), users=seed_users)
        for m in history[:wave]:
            m(cold.catalog)
        ref = cold.run(slow_keyed_project(hi=seed_users - 1, delay=0.0))
        for h in handles:
            assert_outputs_bitwise_equal(h.result, ref)


# --------------------------------------------------- acceptance: BENCH_6 gate
def test_bench6_acceptance():
    """The BENCH_6 scenario (same code CI smokes): an append touching 1% of
    keys re-aggregates <=5% of the rows a cold run reads (bitwise-equal,
    asserted inside run), and the incremental join feeds user fns >=5x fewer
    rows than per-iteration cold runs."""
    from benchmarks import bench6_keyed as b6

    result = b6.run(rows=4000)
    assert result["keyed"]["fresh_fraction"] <= 0.05, result["keyed"]
    assert result["join"]["rows_ratio"] >= 5.0, result["join"]
