"""Minimal, dependency-free stand-in for `hypothesis`.

This container does not ship hypothesis and nothing may be pip-installed,
so ``conftest.py`` registers this module under ``sys.modules["hypothesis"]``
when the real package is absent.  It implements exactly the surface the
test-suite uses — ``given``, ``settings``, and the strategies
``integers/booleans/tuples/lists/sets/sampled_from`` with ``.map`` — as a
seeded random sampler: each ``@given`` test runs ``max_examples`` times
with draws from a PRNG seeded by the test's qualified name, so runs are
deterministic.  No shrinking, no database, no health checks; when the real
hypothesis is available it is always preferred.
"""

from __future__ import annotations

import random
import sys
import types


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._draw(rng)))

    def example_with(self, rng):
        return self._draw(rng)


def integers(min_value=-(2**31), max_value=2**31):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


def tuples(*strats):
    return _Strategy(lambda rng: tuple(s._draw(rng) for s in strats))


def sampled_from(elements):
    pool = list(elements)
    return _Strategy(lambda rng: rng.choice(pool))


def lists(elements, min_size=0, max_size=None):
    hi = max_size if max_size is not None else min_size + 8

    def draw(rng):
        n = rng.randint(min_size, hi)
        return [elements._draw(rng) for _ in range(n)]

    return _Strategy(draw)


def sets(elements, min_size=0, max_size=None):
    hi = max_size if max_size is not None else min_size + 8

    def draw(rng):
        want = rng.randint(min_size, hi)
        out = set()
        # bounded attempts: small sample spaces may not reach `want`
        for _ in range(50 * (want + 1)):
            if len(out) >= want:
                break
            out.add(elements._draw(rng))
        return out

    return _Strategy(draw)


def settings(max_examples=100, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(*strats):
    def deco(fn):
        # zero-arg wrapper (no functools.wraps: pytest must not see the
        # strategy parameters in the signature and treat them as fixtures)
        def runner():
            n = getattr(runner, "_stub_max_examples", 100)
            rng = random.Random(f"{fn.__module__}.{fn.__name__}")
            for _ in range(n):
                fn(*(s._draw(rng) for s in strats))

        runner.__name__ = fn.__name__
        runner.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        runner._stub_max_examples = getattr(fn, "_stub_max_examples", 100)
        return runner

    return deco


def install() -> None:
    """Register this stub as ``hypothesis`` / ``hypothesis.strategies``."""
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "booleans", "tuples", "sampled_from", "lists", "sets"):
        setattr(st, name, globals()[name])
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    hyp.__stub__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
