"""The incrementality contract (ISSUE 3 tentpole): intermediate ``@model``
outputs are cached differentially, and every pipeline edit — feature add/
remove, window widen/narrow, upstream append, function code edit — produces
outputs bitwise-identical to a cold full run while recomputing only the
residual.  The edit sweep itself lives in the shared harness
(``tests/edit_matrix.py``, ISSUE 6), instantiated here for the single-input
rowwise contract; ``test_keyed.py``/``test_multi_input.py`` instantiate the
same matrix for the keyed and multi-input contracts.

Also unit-covers the generalized :class:`DifferentialStore` (the greedy
window-subtraction planner split out of :class:`DifferentialCache`) and the
DSL/DAG validation of the ``incremental="rowwise"`` contract.
"""

import numpy as np
import pytest

from edit_matrix import (
    assert_outputs_bitwise_equal,
    expect_fresh_rows,
    expect_fresh_rows_between,
    expect_zero_rows,
    standard_matrix,
    sweep,
)
from repro.core.cache import DifferentialCache, DifferentialStore
from repro.core.columnar import Table
from repro.core.intervals import IntervalSet
from repro.pipeline import DagError, Model, Project, Workspace, build_dag, model, runtime
from repro.pipeline.dsl import code_fingerprint

SCHEMA = {"eventTime": "<i8", "c1": "<f8", "c2": "<f8", "c3": "<i8"}


def events_table(lo, hi, seed=0):
    n = hi - lo
    rng = np.random.default_rng(seed + lo)
    return Table(
        {
            "eventTime": np.arange(lo, hi, dtype=np.int64),  # unique keys
            "c1": rng.standard_normal(n),
            "c2": rng.standard_normal(n),
            "c3": rng.integers(0, 100, n).astype(np.int64),
        }
    )


def make_workspace(tmp_path, name="lake", rows=1000):
    ws = Workspace(str(tmp_path / name), rows_per_fragment=128)
    ws.catalog.create_table("ns", "raw", SCHEMA, "eventTime")
    ws.catalog.append("ns.raw", events_table(0, rows))
    return ws


def feature_project(hi=799, columns=("c1", "c3"), gain=1.0):
    """cleaned (rowwise drop) -> scaled (rowwise map) — the minimal
    incremental chain, parameterized along the three edit axes."""
    p = Project("feat")
    cols = list(columns)

    @model(project=p, incremental="rowwise")
    @runtime("numpy")
    def cleaned(
        data=Model("ns.raw", columns=cols, filter=f"eventTime BETWEEN 0 AND {hi}")
    ):
        return data.filter(data.column("eventTime") % 10 != 0)

    @model(project=p, incremental="rowwise")
    @runtime("numpy")
    def scaled(data=Model("cleaned")):
        out = {n: data.column(n) for n in data.column_names}
        out["score"] = gain * np.asarray(data.column("c1"), dtype=np.float64)
        return out

    return p


# ----------------------------------------------------- DifferentialStore unit
def _store_elem_data(lo, hi):
    return Table(
        {"k": np.arange(lo, hi, dtype=np.int64), "x": np.arange(lo, hi, dtype=np.float64)}
    )


def test_store_plans_any_signature_differentially():
    store = DifferentialStore()
    sig = ("fnhash", "numpy", ("scan", "t"))
    cost = lambda w: w.measure()
    store.insert_window(sig, "t", "k", IntervalSet.of((0, 50)), _store_elem_data(0, 50))

    plan = store.plan_window(sig, IntervalSet.of((0, 80)), (), cost)
    assert [h.window.to_pairs() for h in plan.hits] == [((0, 50),)]
    assert plan.residual.to_pairs() == ((50, 80),)

    # a different signature sees nothing
    other = store.plan_window(("other",), IntervalSet.of((0, 80)), (), cost)
    assert not other.hits and other.residual.to_pairs() == ((0, 80),)


def test_store_merges_touching_windows_per_signature():
    store = DifferentialStore()
    store.insert_window("s", "t", "k", IntervalSet.of((0, 50)), _store_elem_data(0, 50))
    store.insert_window("s", "t", "k", IntervalSet.of((50, 100)), _store_elem_data(50, 100))
    elems = store.elements("s")
    assert len(elems) == 1
    assert elems[0].window.to_pairs() == ((0, 100),)
    np.testing.assert_array_equal(
        elems[0].data.column("k"), np.arange(0, 100, dtype=np.int64)
    )


def test_store_partial_window_coverage_is_served():
    """Measure-based cost serves cached rows even inside a partially-covered
    region — the property model nodes need and fragment-byte cost can't give."""
    store = DifferentialStore()
    store.insert_window("s", "t", "k", IntervalSet.of((10, 40)), _store_elem_data(10, 40))
    plan = store.plan_window("s", IntervalSet.of((0, 100)), (), lambda w: w.measure())
    assert plan.hits and plan.hits[0].window.to_pairs() == ((10, 40),)
    assert plan.residual.to_pairs() == ((0, 10), (40, 100))


def test_store_lru_eviction_budget():
    elem_bytes = _store_elem_data(0, 100).nbytes
    store = DifferentialStore(max_bytes=3 * elem_bytes)
    for i, sig in enumerate(["a", "b", "c", "d"]):
        store.insert_window(
            sig, "t", "k", IntervalSet.of((0, 100)), _store_elem_data(0, 100)
        )
    assert store.nbytes <= 3 * elem_bytes
    assert store.evictions == 1
    assert store.elements("a") == []  # eldest signature evicted
    assert store.elements("d")


def test_differential_cache_is_a_store_specialization():
    """The scan cache exposes the store surface (shared counters/eviction)."""
    cache = DifferentialCache()
    assert isinstance(cache, DifferentialStore)
    assert cache.lookups == 0 and cache.nbytes == 0


# ------------------------------------------------------------- DSL validation
def test_rowwise_multi_input_accepted():
    """≥2 inputs is the multi-input rowwise contract (an incremental join),
    no longer a structural error — see test_multi_input.py for execution."""
    p = Project("join-ok")

    @model(project=p, incremental="rowwise")
    def join(
        a=Model("ns.x", columns=["c1"]),
        b=Model("ns.y", columns=["c1"]),
    ):
        return a

    dag = build_dag(p)
    assert dag.order == ["join"]


def test_rowwise_requires_windowed_upstream():
    p = Project("bad2")

    @model(project=p)  # default: none
    def agg(data=Model("ns.raw", columns=["c1"])):
        return data

    @model(project=p, incremental="rowwise")
    def downstream(data=Model("agg")):
        return data

    with pytest.raises(DagError, match="windowed"):
        build_dag(p)


def test_unknown_incremental_mode_rejected():
    with pytest.raises(ValueError, match="incremental"):
        model(incremental="columnar")


def test_code_fingerprint_tracks_behaviour_not_model_refs():
    def make(gain, hi):
        def fn(data=Model("ns.raw", columns=["c1"], filter=f"eventTime < {hi}")):
            return {"s": gain * data.column("c1")}

        return fn

    # same behaviour, different window -> same fingerprint (the window is the
    # differential dimension, not identity)
    assert code_fingerprint(make(2.0, 100)) == code_fingerprint(make(2.0, 999))
    # different closed-over constant -> different fingerprint (a code edit)
    assert code_fingerprint(make(2.0, 100)) != code_fingerprint(make(3.0, 100))


def test_code_fingerprint_sees_large_array_closures():
    """repr() elides interior array values ('...'), so closed-over weight
    vectors differing only in the middle must still change the fingerprint —
    the hash reads array bytes, also through containers."""

    def make(weights):
        def fn(data=Model("ns.raw", columns=["c1"])):
            return {"s": data.column("c1") * weights.sum()}

        return fn

    a = np.zeros(5000)
    b = np.zeros(5000)
    b[2500] = 5.0  # invisible to repr()
    assert repr(a) == repr(b)
    assert code_fingerprint(make(a)) != code_fingerprint(make(b))
    assert code_fingerprint(make(a)) == code_fingerprint(make(np.zeros(5000)))

    def make_nested(cfg):
        def fn(data=Model("ns.raw", columns=["c1"])):
            return {"s": data.column("c1") * cfg["w"].sum()}

        return fn

    assert code_fingerprint(make_nested({"w": a})) != code_fingerprint(
        make_nested({"w": b})
    )


# ------------------------------------------------- the incrementality contract
def run_cold(tmp_path, name, project, mutations=()):
    """Fresh workspace + same catalog history -> the reference full run."""
    ws = make_workspace(tmp_path, name)
    for m in mutations:
        m(ws.catalog)
    return ws.run(project)


def _setup(root):
    ws = Workspace(root, rows_per_fragment=128)
    ws.catalog.create_table("ns", "raw", SCHEMA, "eventTime")
    ws.catalog.append("ns.raw", events_table(0, 1000))
    return ws


def test_edit_matrix_rowwise(tmp_path):
    """The full ISSUE-6 edit matrix for the single-input rowwise contract:
    one warm workspace through every edit axis, each answer bitwise-equal to
    a cold replay, with exact residual row counts where they are derivable."""
    append = lambda c: c.append("ns.raw", events_table(1000, 1100, seed=9))
    overwrite = lambda c: c.overwrite_range(
        "ns.raw", 100, 200, events_table(100, 200, seed=77)
    )

    def expect_rerun_served_from_model_cache(warm, cold):
        assert warm.bytes_from_store == 0
        assert warm.bytes_from_model_cache > 0

    def expect_feature_add(warm, cold):
        assert warm.rows_to_user_fns > 0  # schema change: recompute required
        assert "c2" in warm.outputs["scaled"].column_names

    def expect_code_edit(warm, cold):
        # `cleaned` untouched by the gain edit: full hit; `scaled` recomputes
        assert warm.node_stats["cleaned"]["fresh_rows"] == 0
        assert warm.node_stats["scaled"]["fresh_rows"] > 0

    edits = standard_matrix(
        base=dict(hi=499),
        widen=dict(hi=999),
        narrow=dict(hi=299),
        beyond=dict(hi=4999),
        feature_add=dict(hi=4999, columns=("c1", "c2", "c3")),
        feature_remove=dict(hi=4999),
        code_edit=dict(hi=4999, gain=2.0),
        append=append,
        overwrite=overwrite,
        expectations={
            "rerun": expect_rerun_served_from_model_cache,
            # residual (499, 1000): exactly the newly-exposed 500 keys
            "widen": expect_fresh_rows("cleaned", 500),
            # widening past the data's extent: the residual holds no rows
            "beyond": expect_fresh_rows("cleaned", 0),
            "feature-add": expect_feature_add,
            # dropping c2 flips the signature BACK to one the cache still
            # covers over the full window: zero recompute
            "feature-remove": expect_zero_rows,
            # exactly the 100 appended rows, through both stages
            "append": expect_fresh_rows("cleaned", 100),
            # overwritten keys [100, 200) span at most 3 of the 128-row
            # fragments; everything else serves from cache
            "overwrite": expect_fresh_rows_between("cleaned", 1, 384),
            "code-edit": expect_code_edit,
        },
    )
    sweep(tmp_path, _setup, feature_project, edits)


def test_downstream_of_scan_edit_invalidates_through_chain(tmp_path):
    """Editing the scan (feature add) changes the leaf signature component,
    which must propagate: BOTH stages recompute."""
    ws = make_workspace(tmp_path)
    ws.run(feature_project(columns=("c1", "c3")))
    res = ws.run(feature_project(columns=("c1", "c2", "c3")))
    assert res.node_stats["cleaned"]["fresh_rows"] > 0
    assert res.node_stats["scaled"]["fresh_rows"] > 0


def test_warm_full_hit_is_zero_copy(tmp_path):
    ws = make_workspace(tmp_path)
    ws.run(feature_project())
    res = ws.run(feature_project())
    elems = ws.model_store.elements()
    assert elems
    out = res.outputs["scaled"]
    assert any(
        np.shares_memory(out.column("score"), e.data.column("score"))
        for e in elems
        if "score" in e.data.column_names
    ), "a fully-cached model output must be a view over the element buffer"


def test_rowwise_jax_runtime_cached_across_languages(tmp_path):
    """The model store sits below language choice, like the scan cache."""
    p = Project("jaxinc")

    @model(project=p, incremental="rowwise")
    @runtime("jax")
    def jfeat(data=Model("ns.raw", columns=["c1"], filter="eventTime BETWEEN 0 AND 499")):
        import jax.numpy as jnp

        return {k: (v * jnp.float32(2.0) if v.dtype.kind == "f" else v)
                for k, v in data.items()}

    ws = make_workspace(tmp_path)
    r1 = ws.run(p)
    r2 = ws.run(p)
    assert r2.rows_to_user_fns == 0
    assert_outputs_bitwise_equal(r1, r2)


def test_rowwise_fn_creating_rows_rejected(tmp_path):
    p = Project("badrows")

    @model(project=p, incremental="rowwise")
    def doubler(data=Model("ns.raw", columns=["c1"], filter="eventTime < 100")):
        c = data.column("c1")
        return {"c1": np.concatenate([c, c])}

    ws = make_workspace(tmp_path)
    with pytest.raises(ValueError, match="must not\\s+create rows"):
        ws.run(p)


def test_rowwise_dropping_fn_must_return_sort_key(tmp_path):
    p = Project("baddrop")

    @model(project=p, incremental="rowwise")
    def dropper(data=Model("ns.raw", columns=["c1"], filter="eventTime < 100")):
        c = data.column("c1")
        return {"c1": c[c > 0]}  # drops rows, loses the key

    ws = make_workspace(tmp_path)
    with pytest.raises(ValueError, match="sort key"):
        ws.run(p)


def test_none_mode_unaffected_and_default(tmp_path):
    """Existing projects (no contract declared) keep full-recompute
    semantics: the fn sees exactly its declared columns, every run."""
    p = Project("plain")
    seen_cols = []

    @model(project=p)
    def agg(data=Model("ns.raw", columns=["c1"], filter="eventTime < 500")):
        seen_cols.append(data.column_names)
        return {"mean": np.array([data.column("c1").mean()])}

    ws = make_workspace(tmp_path)
    ws.run(p)
    ws.run(p)
    assert seen_cols == [("c1",), ("c1",)]  # no surprise key column
    res = ws.run(p)
    assert res.rows_to_user_fns == 500  # recomputed every run


def test_materialized_rowwise_model_keeps_sort_key(tmp_path):
    """Rowwise outputs are canonicalized to sorted column order, so the
    materializer must take the sort key from the plan, not from 'first
    column' (which would be 'c1' here and mis-sort the published table)."""
    p = Project("matinc")

    @model(project=p, incremental="rowwise", materialize=True)
    def published(
        data=Model("ns.raw", columns=["c1"], filter="eventTime BETWEEN 0 AND 99")
    ):
        return {n: data.column(n) for n in data.column_names}

    ws = make_workspace(tmp_path)
    ws.run(p)
    meta = ws.catalog.table("models.published")
    assert meta.sort_key == "eventTime"


def test_jax_runtime_sort_key_stays_int64(tmp_path):
    """jax x32 truncates int64 to int32 in flight; the engine must restore
    the exact input key (position-aligned), since the key addresses the
    cache — keys >= 2**31 would otherwise wrap and corrupt windowing."""
    p = Project("bigkeys")
    BASE = 2**31  # beyond int32

    @model(project=p, incremental="rowwise")
    @runtime("jax")
    def jmap(data=Model("ns.big", columns=["c1"], filter=f"eventTime >= {BASE}")):
        import jax.numpy as jnp

        return {k: (v * jnp.float32(2.0) if v.dtype.kind == "f" else v)
                for k, v in data.items()}

    ws = Workspace(str(tmp_path / "lake"), rows_per_fragment=128)
    ws.catalog.create_table("ns", "big", {"eventTime": "<i8", "c1": "<f8"}, "eventTime")
    rng = np.random.default_rng(0)
    ws.catalog.append(
        "ns.big",
        Table({
            "eventTime": np.arange(BASE, BASE + 500, dtype=np.int64),
            "c1": rng.standard_normal(500),
        }),
    )
    r1 = ws.run(p)
    keys = r1.outputs["jmap"].column("eventTime")
    assert keys.dtype == np.int64
    np.testing.assert_array_equal(keys, np.arange(BASE, BASE + 500, dtype=np.int64))
    r2 = ws.run(p)  # warm: the restored keys must address the cache exactly
    assert r2.rows_to_user_fns == 0
    assert_outputs_bitwise_equal(r1, r2)


def test_window_widened_beyond_data_has_empty_residual_rows(tmp_path):
    """A residual window holding zero rows (widening past the data's extent)
    must not crash and must stay correct once the rows later appear."""
    ws = make_workspace(tmp_path)  # keys [0, 1000)
    ws.run(feature_project(hi=999))
    res = ws.run(feature_project(hi=4999))  # residual (1000, 5000]: no rows
    assert res.node_stats["cleaned"]["fresh_rows"] == 0
    assert res.outputs["scaled"].num_rows == ws.run(feature_project(hi=999)).outputs["scaled"].num_rows

    # the empty residual was cached with pins; appending rows there must
    # invalidate it and recompute exactly the new rows
    ws.catalog.append("ns.raw", events_table(2000, 2100, seed=3))
    res2 = ws.run(feature_project(hi=4999))
    assert res2.node_stats["cleaned"]["fresh_rows"] == 100
    append = lambda c: c.append("ns.raw", events_table(2000, 2100, seed=3))
    assert_outputs_bitwise_equal(
        res2,
        run_cold(tmp_path, "cold-beyond", feature_project(hi=4999), mutations=[append]),
    )


def test_degenerate_empty_window_runs_fn_on_empty_input(tmp_path):
    p = Project("degenerate")

    @model(project=p, incremental="rowwise")
    def noop(data=Model("ns.raw", columns=["c1"], filter="eventTime BETWEEN 5 AND 1")):
        return {n: data.column(n) for n in data.column_names}

    ws = make_workspace(tmp_path)
    res = ws.run(p)
    out = res.outputs["noop"]
    assert out.num_rows == 0
    assert set(out.column_names) == {"c1", "eventTime"}


# -------------------------------------------------- acceptance: the ≥5× gate
def test_iteration_loop_meets_5x_acceptance(tmp_path):
    """The BENCH_3 iteration loop (same code CI smokes): warm bytes-from-store
    and rows-passed-to-user-fns must drop ≥5× vs per-iteration cold runs,
    with bitwise-equal outputs (asserted inside bench3.run)."""
    from benchmarks import bench3_incremental as b3

    result = b3.run(rows=4000)
    totals = result["totals"]
    assert totals["bytes_ratio"] >= 5.0, totals
    assert totals["rows_ratio"] >= 5.0, totals
