"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes.  Every kernel must match its ref to tight
tolerances; the SSD kernel must additionally match the O(S) sequential
recurrence (an independent second oracle)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import (
    attention_ref,
    dequant,
    dequant_ref,
    flash_attention,
    fragment_gather,
    gather_ref,
    ssd,
    ssd_ref_chunked,
    ssd_ref_sequential,
)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,KV,hd,window",
    [
        (2, 128, 4, 4, 32, 0),     # MHA
        (1, 256, 8, 2, 64, 0),     # GQA 4:1
        (2, 192, 4, 1, 32, 0),     # MQA, S not a block multiple
        (1, 256, 4, 2, 32, 64),    # sliding window
        (1, 64, 2, 2, 16, 0),      # tiny
    ],
)
def test_flash_attention_matches_ref(B, S, H, KV, hd, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    got = flash_attention(q, k, v, window=window, q_block=64, k_block=64, interpret=True)
    want = attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_flash_attention_block_sweep():
    B, S, H, KV, hd = 1, 256, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    want = attention_ref(q, k, v)
    for qb, kb in [(32, 32), (64, 128), (128, 64), (256, 256)]:
        got = flash_attention(q, k, v, q_block=qb, k_block=kb, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_attention_noncausal():
    B, S, H, KV, hd = 1, 128, 2, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    got = flash_attention(q, k, v, causal=False, q_block=64, k_block=64, interpret=True)
    want = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------------- SSD
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,P,N,chunk,hb",
    [
        (2, 128, 4, 16, 32, 32, 2),
        (1, 256, 8, 32, 64, 64, 8),
        (1, 96, 6, 16, 16, 32, 3),   # S pad, H odd block
        (2, 64, 2, 8, 16, 64, 2),    # single chunk
    ],
)
def test_ssd_kernel_matches_chunked_ref(B, S, H, P, N, chunk, hb, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    xh = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N), dtype)
    Cm = jax.random.normal(ks[0], (B, S, N), dtype)

    y, h = ssd(xh, dt, A, Bm, Cm, chunk=chunk, head_block=hb, interpret=True)
    y_ref, h_ref = ssd_ref_chunked(xh, dt, A, Bm, Cm, chunk=chunk)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(y_ref, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), rtol=1e-3, atol=1e-3)


def test_ssd_kernel_matches_sequential_recurrence():
    """Second, independent oracle: the O(S) per-token definition."""
    B, S, H, P, N = 1, 64, 2, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    xh = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    Cm = jax.random.normal(ks[0], (B, S, N), jnp.float32)

    y, h = ssd(xh, dt, A, Bm, Cm, chunk=16, head_block=2, interpret=True)
    y_seq, h_seq = ssd_ref_sequential(xh, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_seq), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_seq), rtol=1e-3, atol=1e-3)


def test_chunked_ref_matches_sequential_ref():
    """Guards against a shared bug in the chunked math itself."""
    B, S, H, P, N = 2, 96, 3, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    xh = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    Cm = jax.random.normal(ks[0], (B, S, N), jnp.float32)
    y_c, h_c = ssd_ref_chunked(xh, dt, A, Bm, Cm, chunk=32)
    y_s, h_s = ssd_ref_sequential(xh, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_s), rtol=1e-3, atol=1e-3)


# --------------------------------------------------------------- gather
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_fragment_gather_contiguous_runs(dtype):
    """Fragment-shaped access: whole aligned runs (fast tiled path)."""
    Ns, C = 64, 40
    src = jnp.arange(Ns * C).reshape(Ns, C).astype(dtype)
    # two fragments: rows [16, 40) then rows [0, 24) — both 8-aligned
    idx = np.concatenate([np.arange(16, 40), np.arange(0, 24)])
    got = fragment_gather(src, idx, row_block=8, col_block=128, interpret=True)
    want = gather_ref(src, jnp.asarray(idx))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fragment_gather_arbitrary_rows():
    """Non-aligned indices take the row-granular fallback."""
    Ns, C = 33, 17
    rng = np.random.default_rng(0)
    src = jnp.asarray(rng.standard_normal((Ns, C)), jnp.float32)
    idx = rng.integers(0, Ns, size=29)
    got = fragment_gather(src, idx, interpret=True)
    want = gather_ref(src, jnp.asarray(idx))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fragment_gather_empty_and_identity():
    src = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4)
    idx = np.arange(8)
    got = fragment_gather(src, idx, row_block=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(src))


# --------------------------------------------------------------- dequant
@pytest.mark.parametrize("out_dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("R,C", [(16, 32), (100, 70), (256, 512), (1, 5)])
def test_dequant_matches_ref(R, C, out_dtype):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(-128, 128, size=(R, C)), jnp.int8)
    scale = jnp.asarray(rng.uniform(0.001, 2.0, size=(C,)), jnp.float32)
    got = dequant(x, scale, out_dtype=out_dtype, row_block=64, col_block=128, interpret=True)
    want = dequant_ref(x, scale, out_dtype=out_dtype)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=1e-2, atol=1e-2
    )


def test_dequant_roundtrip_quantize():
    """int8 quantize → kernel dequantize recovers the original within the
    per-column quantization step (the cache-page codec invariant)."""
    rng = np.random.default_rng(2)
    W = rng.standard_normal((64, 48)).astype(np.float32)
    scale = np.abs(W).max(axis=0) / 127.0
    q = np.clip(np.round(W / scale[None, :]), -127, 127).astype(np.int8)
    got = dequant(jnp.asarray(q), jnp.asarray(scale), out_dtype=jnp.float32, interpret=True)
    np.testing.assert_allclose(np.asarray(got), W, atol=np.abs(W).max() / 100.0)


# ----------------------------------------------- model-integrated fast path
@pytest.mark.parametrize("arch", ["granite-3-2b", "mixtral-8x22b", "mamba2-780m"])
def test_use_pallas_kernels_matches_xla_path(arch):
    """cfg.use_pallas_kernels=True (interpret mode on CPU) must reproduce
    the pure-XLA forward pass — the kernels are a drop-in fast path."""
    import dataclasses

    from repro.models.registry import get_config, get_model

    cfg = get_config(arch).reduced()
    cfg_k = dataclasses.replace(cfg, use_pallas_kernels=True)
    api, api_k = get_model(cfg), get_model(cfg_k)
    params = api.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size)
    out = api.forward(params, toks)
    out_k = api_k.forward(params, toks)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(out_k, np.float32),
        rtol=2e-3, atol=2e-3,
    )
