"""Numerical tests for the model building blocks against slow references."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L
from repro.models.config import ArchConfig
from repro.models.ssm import causal_conv, conv_decode_step, ssd_chunked, ssd_decode_step


def ref_attention(q, k, v, scale, window=0):
    """O(S²) reference with explicit mask."""
    B, S, H, hd = q.shape
    scores = np.einsum("bshk,bthk->bhst", np.asarray(q, np.float32), np.asarray(k, np.float32)) * scale
    qpos = np.arange(S)[:, None]
    kpos = np.arange(S)[None, :]
    mask = kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = np.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(jnp.asarray(scores), axis=-1)
    return np.einsum("bhst,bthk->bshk", np.asarray(probs), np.asarray(v, np.float32))


@pytest.mark.parametrize("S", [2048])
def test_blocked_causal_attention_matches_reference(S, monkeypatch):
    monkeypatch.setattr(L, "_FLASH_QB", 256)
    monkeypatch.setattr(L, "_FLASH_KB", 512)
    rng = np.random.default_rng(0)
    B, H, hd = 2, 4, 32
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    got = L._blocked_causal_attention(q, k, v, hd**-0.5)
    want = ref_attention(q, k, v, hd**-0.5)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("S,W", [(512, 128), (1024, 256)])
def test_blocked_local_attention_matches_reference(S, W):
    rng = np.random.default_rng(1)
    B, H, hd = 2, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    got = L._blocked_local_attention(q, k, v, W, hd**-0.5)
    want = ref_attention(q, k, v, hd**-0.5, window=W)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)


def ref_ssd_sequential(xh, dt, A, Bm, Cm):
    """Token-by-token recurrence: h = exp(dt·A) h + dt·B⊗x ; y = C·h."""
    B_, S, H, P = xh.shape
    N = Bm.shape[-1]
    h = np.zeros((B_, H, P, N), np.float64)
    ys = np.zeros((B_, S, H, P), np.float64)
    xh, dt, A, Bm, Cm = map(lambda a: np.asarray(a, np.float64), (xh, dt, A, Bm, Cm))
    for t in range(S):
        decay = np.exp(dt[:, t] * A[None, :])  # (B,H)
        h = h * decay[:, :, None, None] + np.einsum(
            "bn,bh,bhp->bhpn", Bm[:, t], dt[:, t], xh[:, t]
        )
        ys[:, t] = np.einsum("bhpn,bn->bhp", h, Cm[:, t])
    return ys, h


@pytest.mark.parametrize("S,chunk", [(64, 16), (128, 32), (96, 96)])
def test_ssd_chunked_matches_sequential(S, chunk):
    rng = np.random.default_rng(2)
    B_, H, P, N = 2, 3, 8, 4
    xh = jnp.asarray(rng.standard_normal((B_, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B_, S, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B_, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B_, S, N)), jnp.float32)
    y, h = ssd_chunked(xh, dt, A, Bm, Cm, chunk)
    y_ref, h_ref = ref_ssd_sequential(xh, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=1e-4, atol=1e-4)


def test_ssd_decode_continues_prefill():
    rng = np.random.default_rng(3)
    B_, S, H, P, N = 1, 32, 2, 4, 4
    xh = jnp.asarray(rng.standard_normal((B_, S + 1, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B_, S + 1, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B_, S + 1, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B_, S + 1, N)), jnp.float32)
    _, h = ssd_chunked(xh[:, :S], dt[:, :S], A, Bm[:, :S], Cm[:, :S], 16)
    y_step, _ = ssd_decode_step(xh[:, S], dt[:, S], A, Bm[:, S], Cm[:, S], h)
    y_full, _ = ssd_chunked(xh, dt, A, Bm, Cm, 11 * 3)  # chunk=33 divides 33
    np.testing.assert_allclose(
        np.asarray(y_step), np.asarray(y_full[:, S]), rtol=1e-4, atol=1e-4
    )


def test_causal_conv_matches_decode_steps():
    rng = np.random.default_rng(4)
    B_, S, C, K = 2, 10, 6, 4
    x = jnp.asarray(rng.standard_normal((B_, S, C)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((K, C)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((C,)), jnp.float32)
    full = causal_conv(x, w, b)
    state = jnp.zeros((B_, K - 1, C))
    for t in range(S):
        y, state = conv_decode_step(x[:, t], state, w, b)
        np.testing.assert_allclose(np.asarray(y), np.asarray(full[:, t]), rtol=1e-5, atol=1e-5)


def _moe_cfg(**kw):
    base = dict(
        name="t", family="moe", num_layers=1, d_model=16, num_heads=2,
        num_kv_heads=2, d_ff=32, vocab_size=64, num_experts=4,
        experts_per_token=2, capacity_factor=8.0, dtype="float32",
    )
    base.update(kw)
    return ArchConfig(**base)


def _moe_weights(cfg, key):
    E, D, F = cfg.num_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    return {
        "router": jax.random.normal(ks[0], (D, E)) * 0.1,
        "w1": jax.random.normal(ks[1], (E, D, F)) * D**-0.5,
        "w3": jax.random.normal(ks[2], (E, D, F)) * D**-0.5,
        "w2": jax.random.normal(ks[3], (E, F, D)) * F**-0.5,
    }


def ref_moe(cfg, x, w):
    """Dense reference: every expert on every token, then weighted by gates."""
    B, S, D = x.shape
    xt = np.asarray(x).reshape(-1, D)
    logits = xt @ np.asarray(w["router"])
    topk = np.argsort(-logits, axis=-1)[:, : cfg.experts_per_token]
    sel = np.take_along_axis(logits, topk, axis=-1)
    gates = np.exp(sel - sel.max(-1, keepdims=True))
    gates = gates / gates.sum(-1, keepdims=True)
    out = np.zeros_like(xt)
    for e in range(cfg.num_experts):
        h = xt @ np.asarray(w["w1"][e])
        g = xt @ np.asarray(w["w3"][e])
        y = (h * (1 / (1 + np.exp(-h)))) * g @ np.asarray(w["w2"][e])
        for kslot in range(cfg.experts_per_token):
            m = (topk[:, kslot] == e).astype(np.float32)[:, None]
            out += m * gates[:, kslot : kslot + 1] * y
    return out.reshape(B, S, D)


def test_moe_no_drop_matches_dense_reference():
    cfg = _moe_cfg()
    key = jax.random.PRNGKey(0)
    w = _moe_weights(cfg, key)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    got = L.moe_apply(cfg, x, w)
    want = ref_moe(cfg, x, w)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_tokens():
    cfg = _moe_cfg(capacity_factor=0.25, experts_per_token=1)
    w = _moe_weights(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out = L.moe_apply(cfg, x, w)
    # capacity 0.25 -> most tokens dropped -> many zero rows (but not all)
    zero_rows = np.mean(np.all(np.asarray(out).reshape(-1, cfg.d_model) == 0, axis=-1))
    assert 0.3 < zero_rows < 1.0


def test_rope_preserves_norm_and_relative_phase():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 16))
    pos = jnp.arange(8, dtype=jnp.int32)[None]
    y = L.apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 16))
    def dot_at(i, j):
        qr = L.apply_rope(q, jnp.array([[i]], jnp.int32), 10_000.0)
        kr = L.apply_rope(k, jnp.array([[j]], jnp.int32), 10_000.0)
        return float(jnp.sum(qr * kr))
    assert abs(dot_at(5, 3) - dot_at(7, 5)) < 1e-4
    assert abs(dot_at(5, 3) - dot_at(3, 5)) > 1e-3  # but not symmetric


def test_cross_entropy_masking():
    logits = jnp.zeros((1, 4, 10))
    labels = jnp.zeros((1, 4), jnp.int32)
    mask = jnp.asarray([[1.0, 1.0, 0.0, 0.0]])
    loss, count = L.cross_entropy(logits, labels, mask)
    assert float(count) == 2.0
    np.testing.assert_allclose(float(loss), np.log(10), rtol=1e-5)
