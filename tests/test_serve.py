"""Serving engine: decode correctness, continuous batching, slot reuse.

Ground truth for generation is the training ``forward`` pass: greedy
decoding token-by-token must reproduce argmax over forward logits at every
position (prefill+decode == forward equivalence, per family).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.registry import get_config, get_model
from repro.serve import GenerateRequest, ServeEngine


def _api(arch_id):
    cfg = get_config(arch_id).reduced()
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0))
    return cfg, api, params


def _greedy_via_forward(api, params, prompt, n_new):
    """Reference: rerun the full forward pass for every generated token."""
    toks = list(map(int, prompt))
    out = []
    for _ in range(n_new):
        logits = api.forward(params, jnp.asarray([toks], jnp.int32))
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


FAMILIES = ["granite-3-2b", "mixtral-8x22b", "mamba2-780m", "zamba2-1.2b"]


@pytest.mark.parametrize("arch", FAMILIES)
def test_engine_matches_forward_greedy(arch):
    cfg, api, params = _api(arch)
    rng = np.random.default_rng(0)
    prompt = rng.integers(1, cfg.vocab_size, size=12).astype(np.int32)
    want = _greedy_via_forward(api, params, prompt, 6)

    eng = ServeEngine(api, params, slots=2, max_context=64)
    rid = eng.submit(GenerateRequest(prompt=prompt, max_new_tokens=6))
    results = eng.run_until_drained()
    got = results[rid].tokens.tolist()
    assert got == want, f"{arch}: engine {got} != forward {want}"


def test_mixed_prompt_lengths_are_independent():
    """Two requests with different prompt lengths decode in one batch; each
    must match its own single-request reference (per-sequence positions)."""
    cfg, api, params = _api("granite-3-2b")
    rng = np.random.default_rng(1)
    p1 = rng.integers(1, cfg.vocab_size, size=5).astype(np.int32)
    p2 = rng.integers(1, cfg.vocab_size, size=17).astype(np.int32)
    want1 = _greedy_via_forward(api, params, p1, 5)
    want2 = _greedy_via_forward(api, params, p2, 5)

    eng = ServeEngine(api, params, slots=2, max_context=64)
    r1 = eng.submit(GenerateRequest(prompt=p1, max_new_tokens=5))
    r2 = eng.submit(GenerateRequest(prompt=p2, max_new_tokens=5))
    res = eng.run_until_drained()
    assert res[r1].tokens.tolist() == want1
    assert res[r2].tokens.tolist() == want2


def test_slot_reuse_more_requests_than_slots():
    cfg, api, params = _api("granite-3-2b")
    rng = np.random.default_rng(2)
    prompts = [rng.integers(1, cfg.vocab_size, size=rng.integers(3, 10)).astype(np.int32)
               for _ in range(5)]
    eng = ServeEngine(api, params, slots=2, max_context=64)
    rids = [eng.submit(GenerateRequest(prompt=p, max_new_tokens=4)) for p in prompts]
    res = eng.run_until_drained()
    assert set(res) == set(rids)
    for p, rid in zip(prompts, rids):
        want = _greedy_via_forward(api, params, p, 4)
        assert res[rid].tokens.tolist() == want


def test_eos_stops_generation():
    cfg, api, params = _api("granite-3-2b")
    rng = np.random.default_rng(3)
    prompt = rng.integers(1, cfg.vocab_size, size=8).astype(np.int32)
    ref = _greedy_via_forward(api, params, prompt, 16)
    eos = ref[2]  # force a stop at the 3rd generated token
    eng = ServeEngine(api, params, slots=1, max_context=64)
    rid = eng.submit(GenerateRequest(prompt=prompt, max_new_tokens=16, eos_id=eos))
    res = eng.run_until_drained()
    assert res[rid].tokens.tolist() == ref[: 3]


def test_temperature_sampling_runs():
    cfg, api, params = _api("granite-3-2b")
    rng = np.random.default_rng(4)
    prompt = rng.integers(1, cfg.vocab_size, size=6).astype(np.int32)
    eng = ServeEngine(api, params, slots=1, max_context=64)
    rid = eng.submit(
        GenerateRequest(prompt=prompt, max_new_tokens=8, temperature=0.9, top_k=20)
    )
    res = eng.run_until_drained()
    t = res[rid].tokens
    assert t.shape == (8,)
    assert ((0 <= t) & (t < cfg.vocab_size)).all()
