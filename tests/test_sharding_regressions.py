"""Sharding regression guards for the §Perf hillclimb wins.

Each test lowers a small-but-sharded program on an 8-device fake mesh (in
a subprocess — device count must be set before jax imports) and asserts a
collective-byte budget via the HLO cost model. If a future change
reintroduces one of the diagnosed pathologies (data-dependent MoE
dispatch replication, decode cache owner-broadcast, dropped expert-hidden
constraint), these budgets blow up by 10–1000× and the test fails loudly.
"""

import os
import subprocess
import sys
import textwrap

import pytest

_COMMON = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.dist.sharding import use_rules
from repro.launch.mesh import make_mesh, rules_for
from repro.launch.hlo_cost import analyze_hlo
from repro.models.registry import get_config, get_model
from repro.models import registry

mesh = make_mesh((2, 4), ("data", "model"))
"""


def _run(body: str) -> str:
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", _COMMON + textwrap.dedent(body)],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(__file__)), timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_moe_training_collectives_bounded():
    """MoE train-step collective bytes must stay within ~32× of the token
    bytes (TP psums + dispatch reshard) — the sort-based dispatch measured
    >1000× (EXPERIMENTS §Perf M1)."""
    out = _run(
        """
        cfg = dataclasses.replace(
            get_config("mixtral-8x22b").reduced(),
            num_layers=2, microbatches=1, remat="none", dtype="float32",
        )
        api = get_model(cfg)
        rules = rules_for(cfg, mesh)
        B, S = 8, 128
        with use_rules(rules):
            def loss(p, t):
                lg = api.forward(p, t)
                return jnp.mean(lg.astype(jnp.float32) ** 2)
            g = jax.grad(loss)
            p_sds = jax.eval_shape(api.init_params, jax.ShapeDtypeStruct((2,), jnp.uint32))
            t_sds = jax.ShapeDtypeStruct((B, S), jnp.int32)
            compiled = jax.jit(g).lower(p_sds, t_sds).compile()
        hc = analyze_hlo(compiled.as_text(), 8)
        token_bytes = B * S * cfg.d_model * 4
        param_bytes = sum(
            int(np.prod(l.shape)) * 4
            for l in jax.tree_util.tree_leaves(p_sds)
        )
        # legitimate traffic ~ grad all-reduce (≈2×params) + TP psums
        # (tens of token_bytes); the sort-based dispatch measured >100×
        ratio = hc.collective_bytes / (param_bytes + token_bytes)
        print("RATIO", ratio)
        assert ratio < 60, f"MoE collective blowup: {ratio:.1f}x (params+tokens)"
        """
    )
    assert "RATIO" in out


def test_decode_no_cache_owner_broadcast():
    """B=1 decode must not move cache-sized collectives (EXPERIMENTS §Perf
    Z1/Z4: the owner-broadcast moved the FULL KV cache per layer)."""
    out = _run(
        """
        cfg = dataclasses.replace(
            get_config("granite-3-2b").reduced(), num_layers=2, dtype="float32"
        )
        api = get_model(cfg)
        rules = rules_for(cfg, mesh)
        B, T = 1, 256
        with use_rules(rules):
            p_sds = jax.eval_shape(api.init_params, jax.ShapeDtypeStruct((2,), jnp.uint32))
            cache_sds = jax.eval_shape(lambda: api.init_decode_cache(B, T))
            tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
            compiled = jax.jit(api.decode_step).lower(p_sds, tok, cache_sds).compile()
        hc = analyze_hlo(compiled.as_text(), 8)
        cache_bytes = sum(
            int(np.prod(l.shape)) * l.dtype.itemsize
            for l in jax.tree_util.tree_leaves(cache_sds)
        )
        ratio = hc.collective_bytes / cache_bytes
        print("RATIO", ratio)
        assert ratio < 0.5, f"decode moves {ratio:.2f}x the cache over the wire"
        """
    )
    assert "RATIO" in out


def test_uneven_heads_still_sharded():
    """Dims larger than (but not divisible by) the axis keep their
    constraint (EXPERIMENTS §Perf L1): a 6-head attention on a 4-way model
    axis must not replicate the (B,H,S,S) score buffer."""
    out = _run(
        """
        from repro.dist.sharding import shard, MeshRules, _base_rules
        rules = MeshRules(rules=_base_rules(pod=False), mesh=mesh)
        with use_rules(rules):
            def f(x):
                return shard(x, ("batch", "act_heads", None, None)) * 2.0
            sds = jax.ShapeDtypeStruct((2, 6, 64, 64), jnp.float32)
            compiled = jax.jit(f).lower(sds).compile()
        txt = compiled.as_text()
        # per-device head dim must be ceil(6/4)=2, not 6 (replicated)
        assert "f32[1,2,64,64]" in txt, txt[-1500:]
        print("SHARDED_OK")
        """
    )
    assert "SHARDED_OK" in out


def test_size1_batch_not_parked_on_one_device():
    """Size-1 dims must NOT be constrained onto a bigger axis (the Z4
    owner-broadcast hazard): the constraint is dropped."""
    out = _run(
        """
        from repro.dist.sharding import shard, MeshRules, _base_rules
        rules = MeshRules(rules=_base_rules(pod=False), mesh=mesh)
        with use_rules(rules):
            def f(x):
                return shard(x, ("batch", None)) + 1.0
            sds = jax.ShapeDtypeStruct((1, 64), jnp.float32)
            compiled = jax.jit(f).lower(sds).compile()
        txt = compiled.as_text()
        assert "f32[1,64]" in txt  # full row everywhere, not parked
        print("DROPPED_OK")
        """
    )
    assert "DROPPED_OK" in out
