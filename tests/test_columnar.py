"""Tests for the Arrow-analog columnar layer: zero-copy semantics + IPC."""

import numpy as np
import pytest

from repro.core.columnar import ChunkedTable, Table, concat_tables, read_ipc, write_ipc


def make_table(n=100, seed=0):
    rng = np.random.default_rng(seed)
    return Table(
        {
            "ts": np.arange(n, dtype=np.int64),
            "x": rng.standard_normal(n),
            "y": rng.integers(0, 1000, n).astype(np.int32),
        }
    )


def test_select_is_zero_copy():
    t = make_table()
    view = t.select(["x", "ts"])
    assert np.shares_memory(view.column("x"), t.column("x"))
    assert np.shares_memory(view.column("ts"), t.column("ts"))


def test_slice_is_zero_copy():
    t = make_table()
    view = t.slice(10, 50)
    assert view.num_rows == 40
    assert np.shares_memory(view.column("x"), t.column("x"))


def test_columns_are_immutable():
    t = make_table()
    with pytest.raises(ValueError):
        t.column("x")[0] = 42.0


def test_caller_array_stays_writable():
    """Constructing a Table must not flip the writeable flag on the CALLER's
    array — only the Table's internal view is frozen (still zero-copy)."""
    arr = np.arange(8, dtype=np.float64)
    t = Table({"x": arr})
    assert arr.flags.writeable, "caller's array was mutated in place"
    assert not t.column("x").flags.writeable
    assert np.shares_memory(t.column("x"), arr)  # still a zero-copy view
    with pytest.raises(ValueError):
        t.column("x")[0] = 1.0


def test_k_consumers_share_one_buffer():
    # the paper's Arrow-view argument: k children of one scan share memory
    t = make_table(1000)
    views = [t.select(["x"]).slice(0, 1000) for _ in range(8)]
    for v in views:
        assert np.shares_memory(v.column("x"), t.column("x"))


def test_chunked_table_assembly_and_combine():
    a, b = make_table(10, 1), make_table(7, 2)
    ct = ChunkedTable([a, b])
    assert ct.num_rows == 17
    combined = ct.combine()
    assert combined.num_rows == 17
    np.testing.assert_array_equal(
        combined.column("x"), np.concatenate([a.column("x"), b.column("x")])
    )
    # chunks themselves are not copied by assembly
    assert np.shares_memory(ct.chunks[0].column("x"), a.column("x"))


def test_chunked_schema_mismatch_raises():
    a = make_table(5)
    b = a.select(["x"])
    with pytest.raises(ValueError):
        ChunkedTable([a, b])


def test_ipc_roundtrip_and_mmap(tmp_path):
    t = make_table(512)
    path = str(tmp_path / "t.ripc")
    nbytes = write_ipc(t, path)
    assert nbytes > t.nbytes  # header + alignment padding
    back = read_ipc(path, mmap=True)
    assert back.equals(t)
    back2 = read_ipc(path, mmap=False)
    assert back2.equals(t)


def test_sort_and_take():
    t = Table({"ts": np.array([3, 1, 2], dtype=np.int64), "v": np.array([30.0, 10.0, 20.0])})
    s = t.sort_by("ts")
    np.testing.assert_array_equal(s.column("ts"), [1, 2, 3])
    np.testing.assert_array_equal(s.column("v"), [10.0, 20.0, 30.0])


def test_empty_chunked():
    ct = ChunkedTable([])
    assert ct.num_rows == 0
    assert ct.combine().num_rows == 0


def test_chunked_column_touches_only_that_column():
    """ChunkedTable.column() must not combine() the whole table: reading one
    column of a k-column chunked frame concatenates only that column."""
    a, b = make_table(10, 1), make_table(7, 2)
    ct = ChunkedTable([a, b])
    np.testing.assert_array_equal(
        ct.column("x"), np.concatenate([a.column("x"), b.column("x")])
    )
    # single-chunk fast path is zero-copy
    one = ChunkedTable([a])
    assert np.shares_memory(one.column("x"), a.column("x"))
    with pytest.raises(KeyError):
        ct.column("nope")
    with pytest.raises(KeyError):
        ChunkedTable([]).column("x")


def test_write_ipc_accepts_file_objects(tmp_path):
    """Streaming spill path: write_ipc into an open handle produces the
    byte-identical file the path variant does."""
    t = make_table(257)
    p1, p2 = str(tmp_path / "a.ripc"), str(tmp_path / "b.ripc")
    n1 = write_ipc(t, p1)
    with open(p2, "wb") as f:
        n2 = write_ipc(t, f)
    assert n1 == n2
    with open(p1, "rb") as f1, open(p2, "rb") as f2:
        assert f1.read() == f2.read()
    assert read_ipc(p2, mmap=True).equals(t)


def test_write_ipc_handles_noncontiguous_and_empty_columns(tmp_path):
    base = make_table(64)
    # a strided view (every other row) is not C-contiguous
    strided = Table({"x": base.column("x")[::2]})
    path = str(tmp_path / "s.ripc")
    write_ipc(strided, path)
    assert read_ipc(path).equals(Table({"x": np.ascontiguousarray(base.column("x")[::2])}))
    empty = base.slice(0, 0)
    path2 = str(tmp_path / "e.ripc")
    write_ipc(empty, path2)
    back = read_ipc(path2)
    assert back.num_rows == 0
    assert back.column_names == empty.column_names
    assert back.schema() == empty.schema()
