"""repro.lint corpus matrices (ISSUE 7 satellite).

False-positive matrix: every rowwise/keyed function in the shipped
examples and the incrementality test-suites must lint clean — the
verifier is useless if the repo's own idioms trip it.  True-positive
matrix: seeded violations must be caught through the same CLI entry
points users run, with stable codes and file:line locations."""

import json
import textwrap

import pytest

import repro.lint as lint
from repro.analysis import ContractError

CLEAN_CORPUS = [
    "examples/quickstart.py",
    "examples/incremental_iteration.py",
    "examples/incremental_join.py",
    "examples/multi_user_cache.py",
    "examples/multi_tenant_service.py",
    "examples/serve_batch.py",
    "examples/train_e2e.py",
    "tests/edit_matrix.py",
    "tests/test_keyed.py",
    "tests/test_multi_input.py",
]


@pytest.mark.parametrize("path", CLEAN_CORPUS)
def test_corpus_lints_clean(path):
    findings, errors = lint.lint_targets([path])
    assert errors == [], errors
    assert findings == [], "\n".join(f.render() for f in findings)


def test_src_tree_lints_clean():
    findings, errors = lint.lint_targets(["src/repro"])
    assert errors == [], errors
    assert findings == [], "\n".join(f.render() for f in findings)


# ------------------------------------------------------ true-positive fixture
BAD_SOURCE = textwrap.dedent(
    '''
    """Seeded-violation fixture: one finding per code the linter ships."""
    import random
    import numpy as np

    from repro.pipeline import Model, Project, model

    project = Project("bad")
    EVENTS = Model("ns.events", columns=["v1"], filter="t BETWEEN 0 AND 9")


    @model(project=project, incremental="rowwise")
    def running_total(data=EVENTS):          # RPR001: cross-row cumsum
        return {"t": np.cumsum(np.asarray(data.column("v1")))}


    @model(project=project, incremental="rowwise")
    def jittered(data=EVENTS):               # RPR002: unseeded randomness
        return {"v": np.asarray(data.column("v1")) * random.random()}


    _SEEN = []


    @model(project=project, incremental="rowwise")
    def logged(data=EVENTS):                 # RPR003: mutates module state
        _SEEN.append(data.num_rows)
        return {"v": data.column("v1")}
    '''
)


@pytest.fixture
def bad_module(tmp_path):
    path = tmp_path / "bad_pipeline.py"
    path.write_text(BAD_SOURCE)
    return str(path)


def test_seeded_violations_caught_with_locations(bad_module):
    findings, errors = lint.lint_targets([bad_module])
    assert errors == []
    by_code = {f.code for f in findings}
    assert {"RPR001", "RPR002", "RPR003"} <= by_code
    for f in findings:
        assert f.filename.endswith("bad_pipeline.py")
        assert f.lineno > 0
        assert ":" in f.location()


def test_rpr004_and_rpr005_reported_via_declared_scopes(tmp_path):
    src = textwrap.dedent(
        """
        from repro.pipeline import Model, Project, model

        project = Project("scoped-bad")
        EVENTS = Model("ns.events", columns=["v1", "v2"], filter="t BETWEEN 0 AND 9")

        def build():
            @model(project=project, incremental="rowwise", reads=("v1",))
            def leaky(data=EVENTS):          # RPR005: reads v2 undeclared
                return {"v": data.column("v1"), "w": data.column("v2")}
        """
    )
    path = tmp_path / "scoped_bad.py"
    path.write_text(src)
    # decoration raises at import time — the CLI surfaces it as a finding
    # or an import error, never a silent pass
    findings, errors = lint.lint_targets([str(path)])
    assert any("RPR005" in e for e in errors) or any(
        f.code == "RPR005" for f in findings
    )


def test_cli_exit_codes(bad_module, capsys):
    assert lint.main(["examples/quickstart.py"]) == 0
    assert "clean" in capsys.readouterr().out

    assert lint.main([bad_module]) == 1
    out = capsys.readouterr().out
    assert "RPR001" in out and "bad_pipeline.py" in out

    assert lint.main([str(bad_module) + ".does-not-exist"]) == 2


def test_cli_json_output(bad_module, capsys):
    assert lint.main(["--format", "json", bad_module]) == 1
    payload = json.loads(capsys.readouterr().out)
    codes = {f["code"] for f in payload}
    assert "RPR001" in codes
    for f in payload:
        assert f["file"] and f["line"]


def test_verify_false_models_are_skipped(tmp_path):
    src = textwrap.dedent(
        """
        import numpy as np
        from repro.pipeline import Model, Project, model

        project = Project("optout")
        EVENTS = Model("ns.events", columns=["v1"], filter="t BETWEEN 0 AND 9")

        @model(project=project, incremental="rowwise", verify=False)
        def deliberate(data=EVENTS):
            return {"t": np.cumsum(np.asarray(data.column("v1")))}
        """
    )
    path = tmp_path / "optout_pipeline.py"
    path.write_text(src)
    findings, errors = lint.lint_targets([str(path)])
    assert errors == []
    assert findings == []
