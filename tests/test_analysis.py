"""repro.analysis (ISSUE 7): the bytecode contract verifier.

True-positive matrix (one fixture per finding code), scope-inference
units, the strict=False / verify=False demotions, ContractError
file:line routing, and the code_fingerprint transitive-helper
regression (edit a helper -> fingerprint must change)."""

import textwrap
import warnings

import numpy as np
import pytest

from repro.analysis import (
    CROSS_ROW_OP,
    HIDDEN_STATE,
    NONDETERMINISM,
    UNKNOWN,
    ContractError,
    analyze_model_fn,
    referenced_functions,
)
from repro.pipeline import Model, Project, build_dag, compile_plan, model
from repro.pipeline.dsl import code_fingerprint

EVENTS = Model("ns.events", columns=["v1", "v2"], filter="eventTime BETWEEN 0 AND 99")


def analysis_of(fn, incremental="rowwise", params=("data",)):
    return analyze_model_fn(
        fn, incremental=incremental, table_params=params, name=fn.__name__
    )


# ------------------------------------------------------- true-positive matrix
def test_rpr001_cross_row_op_in_rowwise():
    def running(data=EVENTS):
        return {"t": np.cumsum(np.asarray(data.column("v1")))}

    codes = [f.code for f in analysis_of(running).findings]
    assert CROSS_ROW_OP in codes


def test_rpr001_sort_and_shift_variants():
    def sorting(data=EVENTS):
        return {"v": np.sort(np.asarray(data.column("v1")))}

    def shifted(data=EVENTS):
        return {"d": np.diff(np.asarray(data.column("v1")))}

    for fn in (sorting, shifted):
        assert any(f.code == CROSS_ROW_OP for f in analysis_of(fn).findings), fn


def test_rpr001_not_flagged_for_keyed_reducers():
    """diff/reduceat/unique are the keyed-aggregation idiom — RPR001 is a
    rowwise-only check."""

    def agg(data=EVENTS):
        users = np.asarray(data.column("v1"))
        uniq, starts = np.unique(users, return_index=True)
        return {
            "user": uniq,
            "total": np.add.reduceat(users, starts),
            "n": np.diff(np.append(starts, users.size)),
        }

    assert analysis_of(agg, incremental="keyed").findings == []


def test_rpr002_nondeterminism_random_time_uuid():
    def drawn(data=EVENTS):
        import random

        return {"v": np.asarray(data.column("v1")) * random.random()}

    def clocked(data=EVENTS):
        import time

        return {"v": np.asarray(data.column("v1")) + time.time()}

    def tagged(data=EVENTS):
        import uuid

        return {"v": data.column("v1"), "tag": str(uuid.uuid4())}

    def np_global(data=EVENTS):
        return {"v": np.asarray(data.column("v1")) + np.random.random()}

    for fn in (drawn, clocked, tagged, np_global):
        codes = [f.code for f in analysis_of(fn).findings]
        assert NONDETERMINISM in codes, fn.__name__


def test_rpr002_seeded_rng_and_sleep_are_clean():
    def seeded(data=EVENTS):
        rng = np.random.default_rng(42)
        return {"v": np.asarray(data.column("v1")) + rng.standard_normal(1)[0]}

    def sleepy(data=EVENTS):
        import time

        time.sleep(0.001)
        return {"v": data.column("v1")}

    for fn in (seeded, sleepy):
        assert analysis_of(fn).findings == [], fn.__name__


def test_rpr002_unseeded_default_rng_flagged():
    def unseeded(data=EVENTS):
        rng = np.random.default_rng()
        return {"v": np.asarray(data.column("v1")) + rng.standard_normal(1)[0]}

    assert any(f.code == NONDETERMINISM for f in analysis_of(unseeded).findings)


_SINK = []


def test_rpr003_hidden_state():
    def stores_global(data=EVENTS):
        global _STATE
        _STATE = 1
        return {"v": data.column("v1")}

    def mutates_captured(data=EVENTS):
        _SINK.append(1)
        return {"v": data.column("v1")}

    for fn in (stores_global, mutates_captured):
        codes = [f.code for f in analysis_of(fn).findings]
        assert HIDDEN_STATE in codes, fn.__name__


def test_rpr003_np_append_is_not_mutation():
    """np.append is a pure function on a module — the mutator-name check
    must not fire on module attributes."""

    def appends(data=EVENTS):
        v = np.asarray(data.column("v1"))
        return {"v": np.append(v, [0.0])}

    assert analysis_of(appends).findings == []


def test_rpr003_found_transitively_in_helper():
    src = textwrap.dedent(
        """
        _LOG = []
        def log_it(x):
            _LOG.append(x)
            return x
        def m(data):
            return {"v": log_it(data.column("v1"))}
        """
    )
    ns = {}
    exec(src, ns)
    ns["m"].__module__ = "__main__"
    findings = analyze_model_fn(
        ns["m"], incremental="rowwise", table_params=("data",), name="m"
    ).findings
    assert any(f.code == HIDDEN_STATE and f.helper == "log_it" for f in findings)


def test_rpr005_undeclared_read_raises_at_decoration():
    p = Project("rpr005")
    with pytest.raises(ContractError, match="RPR005") as ei:
        @model(project=p, incremental="rowwise", reads=("v1",))
        def leaky(data=EVENTS):
            return {"v": np.asarray(data.column("v1")) + np.asarray(data.column("v2"))}

    assert "test_analysis.py" in str(ei.value)
    assert ei.value.lineno is not None


def test_rpr004_undeclared_write_raises_at_decoration():
    p = Project("rpr004")
    with pytest.raises(ContractError, match="RPR004"):
        @model(project=p, incremental="rowwise", writes=("v",))
        def chatty(data=EVENTS):
            return {"v": data.column("v1"), "extra": data.column("v2")}


# -------------------------------------------------------------- inference
def test_scope_inference_proven_patterns():
    def reader(data=EVENTS):
        a = np.asarray(data.column("v1"))
        b = np.asarray(data["v2"])
        c = data.get("flag", 0)
        n = data.num_rows
        return {"s": a + b, "flag": c, "n2": np.full(n, 0)}

    ana = analysis_of(reader)
    assert ana.reads == frozenset({"v1", "v2", "flag"})
    assert ana.writes == frozenset({"s", "flag", "n2"})


def test_scope_inference_alias_tracking():
    def aliased(data=EVENTS):
        d = data
        return {"v": d.column("v1")}

    assert analysis_of(aliased).reads == frozenset({"v1"})


def test_scope_inference_escape_is_unknown():
    def filters(data=EVENTS):
        return data.filter(data.column("flag") > 0)

    def dynamic(data=EVENTS):
        return {n: data.column(n) for n in data.column_names}

    def passed(data=EVENTS):
        return {"v": len(data)}

    for fn in (filters, dynamic, passed):
        assert analysis_of(fn).reads is UNKNOWN, fn.__name__


def test_scope_inference_comprehension_reads_const_key():
    def comp(data=EVENTS):
        return {"v": [x for x in data.column("v1")]}

    ana = analysis_of(comp)
    assert ana.reads == frozenset({"v1"})


def test_scope_alias_created_after_use_is_still_seen():
    """Soundness regression: ``d = data`` sits at the END of the loop
    body, so ``d["v2"]`` earlier in the body is only reachable through
    the back-edge — a single linear pass proved reads={"v1"} and cached
    windows survived edits to the genuinely-read "v2".  The fixpoint
    re-scan must count it (proven superset or UNKNOWN, never smaller)."""

    def sneaky(data=EVENTS):
        total = np.zeros(data.num_rows)
        for i in range(2):
            if i:
                total = total + np.asarray(d["v2"])
            d = data
        return {"t": np.asarray(data["v1"]) + total}

    ana = analysis_of(sneaky)
    assert ana.reads is UNKNOWN or "v2" in ana.reads
    assert ana.reads is UNKNOWN or ana.reads == frozenset({"v1", "v2"})


def test_memo_not_shared_across_closure_helper_siblings():
    """Factory-created models share one code object but differ in the
    closure helper they call; the memo must not hand one sibling the
    other's verdict (missed RPR002 one way, spurious RPR002 the other)."""

    def make(helper):
        def m(data=EVENTS):
            return {"v": helper(np.asarray(data.column("v1")))}

        return m

    def pure(x):
        return x * 2

    def dirty(x):
        import random

        return x * random.random()

    assert analysis_of(make(pure)).findings == []
    assert any(
        f.code == NONDETERMINISM for f in analysis_of(make(dirty)).findings
    )
    # clean sibling analyzed AFTER the dirty one must stay clean too
    assert analysis_of(make(pure)).findings == []


def test_unsupported_interpreter_abstains(monkeypatch):
    """The opname patterns are CPython 3.10/3.11 shapes; on other
    interpreters the analyzer must return no findings and all-UNKNOWN
    scopes rather than silently half-working (e.g. 3.13's fused
    LOAD_FAST_LOAD_FAST would hide table loads from the scope pass)."""
    from repro.analysis import walker as W

    monkeypatch.setattr(W, "_SUPPORTED_INTERPRETER", False)

    def running(data=EVENTS):
        return {"t": np.cumsum(np.asarray(data.column("v1")))}

    ana = analysis_of(running)
    assert ana.findings == []
    assert ana.reads is UNKNOWN and ana.writes is UNKNOWN


def test_augmented_subscript_write_abstains():
    """``out["b"] += …`` compiles to ROT_THREE/STORE_SUBSCR with no
    LOAD_FAST at i-2 — it must force writes to UNKNOWN, not be silently
    dropped from the proven write set."""

    def aug(data=EVENTS):
        out = {}
        out["a"] = np.asarray(data.column("v1"))
        out["b"] = np.zeros(data.num_rows)
        out["b"] += 1.0
        return out

    assert analysis_of(aug).writes is UNKNOWN


# --------------------------------------------- dag-time verdicts & demotions
def violating_project(**model_kw):
    p = Project("viol")

    @model(project=p, incremental="rowwise", **model_kw)
    def bad(data=EVENTS):
        return {"t": np.cumsum(np.asarray(data.column("v1")))}

    return p


def test_build_dag_raises_contract_error_with_location():
    with pytest.raises(ContractError, match="RPR001") as ei:
        build_dag(violating_project())
    assert ei.value.model == "bad"
    assert "test_analysis.py" in ei.value.filename
    assert str(ei.value.lineno) in str(ei.value)


def test_build_dag_strict_false_demotes_to_warning():
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        dag = build_dag(violating_project(), strict=False)
    assert dag.order == ["bad"]
    assert any("RPR001" in str(w.message) for w in caught)


def test_verify_false_opts_out():
    build_dag(violating_project(verify=False))  # no raise, no warning needed


def test_bad_incremental_value_is_contract_error():
    # no function exists yet, so no name/location to carry — but it must
    # still be a ValueError for backwards compatibility
    with pytest.raises(ContractError):
        model(incremental="columnar")
    with pytest.raises(ValueError, match="incremental"):
        model(incremental="columnar")


def test_mismatched_sort_keys_is_contract_error(tmp_path):
    from repro.pipeline import Workspace

    ws = Workspace(str(tmp_path / "ws"), rows_per_fragment=256)
    ws.catalog.create_table("ns", "a", {"ka": "<i8", "x": "<f8"}, "ka")
    ws.catalog.create_table("ns", "b", {"kb": "<i8", "y": "<f8"}, "kb")
    from repro.core.columnar import Table

    ws.catalog.append("ns.a", Table({"ka": np.arange(4), "x": np.zeros(4)}))
    ws.catalog.append("ns.b", Table({"kb": np.arange(4), "y": np.zeros(4)}))

    p = Project("mismatch")

    @model(project=p, incremental="rowwise")
    def joined(
        left=Model("ns.a", columns=["x"]),
        right=Model("ns.b", columns=["y"]),
    ):
        return {"ka": left.column("ka"), "x": left.column("x")}

    with pytest.raises(ContractError, match="share one sort key") as ei:
        ws.run(p)
    assert ei.value.model == "joined"
    assert "test_analysis.py" in str(ei.value)


def test_missing_columns_is_contract_error(tmp_path):
    from repro.pipeline import Workspace

    ws = Workspace(str(tmp_path / "ws"), rows_per_fragment=256)
    ws.catalog.create_table("ns", "t", {"k": "<i8", "x": "<f8"}, "k")
    p = Project("nocols")

    @model(project=p)
    def scans(data=Model("ns.t")):
        return {"k": data.column("k")}

    with pytest.raises(ContractError, match="must declare columns=") as ei:
        ws.run(p)
    assert ei.value.model == "scans"


# ------------------------------------------- fingerprint helper regression
def _fingerprint_of(src):
    # no __name__ in the namespace, so the exec'd functions carry
    # __module__=None — which the analyzer treats as user code
    ns = {"np": np}
    exec(textwrap.dedent(src), ns)
    return code_fingerprint(ns["m"])


def test_fingerprint_changes_when_helper_edited():
    """The ISSUE-7 satellite regression: pre-PR, editing a module-level
    helper a model calls did NOT change the model's fingerprint, so warm
    runs served stale windows."""
    f1 = _fingerprint_of(
        """
        def scale(x):
            return x * 2
        def m(data):
            return {"v": scale(data.column("v1"))}
        """
    )
    f2 = _fingerprint_of(
        """
        def scale(x):
            return x * 3
        def m(data):
            return {"v": scale(data.column("v1"))}
        """
    )
    assert f1 != f2


def test_fingerprint_changes_when_transitive_helper_edited():
    base = """
        def inner(x):
            return x {op} 1
        def outer(x):
            return inner(x)
        def m(data):
            return {{"v": outer(data.column("v1"))}}
        """
    assert _fingerprint_of(base.format(op="+")) != _fingerprint_of(
        base.format(op="-")
    )


def test_fingerprint_stable_across_identical_definitions():
    src = """
        def scale(x):
            return x * 2
        def m(data):
            return {"v": scale(data.column("v1"))}
        """
    assert _fingerprint_of(src) == _fingerprint_of(src)


def test_fingerprint_ignores_library_function_bodies():
    """numpy internals must not enter the hash (fragile across versions,
    megabytes of code) — library refs are pinned by qualified name."""

    def m(data):
        return {"v": np.asarray(data)}

    helpers = referenced_functions(m)
    assert all(h.__module__.split(".")[0] != "numpy" for h in helpers)


def test_fingerprint_recursion_handles_cycles():
    src = """
        def a(x):
            return b(x)
        def b(x):
            return a(x)
        def m(data):
            return {"v": a(data.column("v1"))}
        """
    assert isinstance(_fingerprint_of(src), str)
