"""Multi-input rowwise incrementality (ISSUE 6 tentpole): an
``incremental="rowwise"`` model over >=2 inputs is an incremental sort-merge
join.  All inputs must share one sort key; the node's window is the
INTERSECTION of the input windows; cache elements pin fragments of EVERY
leaf table (labeled pins), so an edit on one side invalidates exactly that
side's key range; the executor feeds the user fn zip-aligned residual
slices of each input, and the UNION with cached hits is bitwise-identical
to a cold run across the full edit matrix.
"""

import numpy as np
import pytest

from edit_matrix import (
    assert_outputs_bitwise_equal,
    expect_fresh_rows,
    expect_fresh_rows_between,
    expect_zero_rows,
    standard_matrix,
    sweep,
)
from repro.core.columnar import Table
from repro.pipeline import DagError, Model, Project, Workspace, build_dag, model, runtime

SCHEMA_L = {"eventTime": "<i8", "lx": "<f8", "lz": "<f8"}
SCHEMA_R = {"eventTime": "<i8", "ry": "<f8"}


def left_table(lo, hi, seed=0):
    n = hi - lo
    rng = np.random.default_rng(seed + lo)
    return Table(
        {
            "eventTime": np.arange(lo, hi, dtype=np.int64),
            "lx": rng.standard_normal(n),
            "lz": rng.standard_normal(n),
        }
    )


def right_table(lo, hi, seed=1):
    keys = np.arange(lo + (lo % 2), hi, 2, dtype=np.int64)  # even keys only
    rng = np.random.default_rng(seed + lo)
    return Table({"eventTime": keys, "ry": rng.standard_normal(keys.size)})


def make_workspace(root):
    ws = Workspace(root, rows_per_fragment=128)
    ws.catalog.create_table("ns", "left", SCHEMA_L, "eventTime")
    ws.catalog.create_table("ns", "right", SCHEMA_R, "eventTime")
    ws.catalog.append("ns.left", left_table(0, 1000))
    ws.catalog.append("ns.right", right_table(0, 1000))
    return ws


def join_project(hi=499, l_hi=None, r_hi=None, columns=("lx",), gain=1.0):
    """joined (multi-input rowwise: incremental sort-merge inner join) ->
    scaled (rowwise map), parameterized along the edit axes.  ``l_hi`` /
    ``r_hi`` widen one side's window independently of the other."""
    p = Project("join")
    cols = list(columns)
    lh = hi if l_hi is None else l_hi
    rh = hi if r_hi is None else r_hi

    @model(project=p, incremental="rowwise")
    @runtime("numpy")
    def joined(
        left=Model("ns.left", columns=cols, filter=f"eventTime BETWEEN 0 AND {lh}"),
        right=Model("ns.right", columns=["ry"], filter=f"eventTime BETWEEN 0 AND {rh}"),
    ):
        lk = np.asarray(left.column("eventTime"))
        rk = np.asarray(right.column("eventTime"))
        common, li, ri = np.intersect1d(lk, rk, return_indices=True)
        out = {"eventTime": common, "ry": np.asarray(right.column("ry"))[ri]}
        for n in left.column_names:
            if n != "eventTime":
                out[n] = np.asarray(left.column(n))[li]
        return out

    @model(project=p, incremental="rowwise")
    @runtime("numpy")
    def scaled(data=Model("joined")):
        out = {n: data.column(n) for n in data.column_names}
        out["score"] = gain * (
            np.asarray(data.column("lx"), np.float64)
            + np.asarray(data.column("ry"), np.float64)
        )
        return out

    return p


# ------------------------------------------------------- compile-time checks
def test_mismatched_sort_keys_rejected(tmp_path):
    p = Project("badkeys")

    @model(project=p, incremental="rowwise")
    def join(
        a=Model("ns.left", columns=["lx"], filter="eventTime BETWEEN 0 AND 99"),
        b=Model("ns.other", columns=["oy"], filter="ts BETWEEN 0 AND 99"),
    ):
        return a

    ws = make_workspace(str(tmp_path / "lake"))
    ws.catalog.create_table("ns", "other", {"ts": "<i8", "oy": "<f8"}, "ts")
    ws.catalog.append(
        "ns.other",
        Table({"ts": np.arange(100, dtype=np.int64), "oy": np.zeros(100)}),
    )
    with pytest.raises(ValueError, match="share one sort key"):
        ws.run(p)


def test_multi_input_requires_windowed_inputs():
    p = Project("badwin")

    @model(project=p)  # none: its output carries no sort-key window
    def prep(data=Model("ns.left", columns=["lx"])):
        return data

    @model(project=p, incremental="rowwise")
    def join(
        a=Model("prep"),
        b=Model("ns.right", columns=["ry"], filter="eventTime BETWEEN 0 AND 99"),
    ):
        return a

    with pytest.raises(DagError, match="windowed"):
        build_dag(p)


# ------------------------------------------------------------ the edit matrix
def test_edit_matrix_multi_input_join(tmp_path):
    """The full ISSUE-6 edit matrix for the join: left 1000 rows (every
    key), right 500 rows (even keys), edits land on EITHER side and must
    invalidate only that side's key range via the labeled pins."""
    # left-side append: keys [1000, 1100) — the right table has no rows
    # there, so exactly the 100 left rows reach the join
    append = lambda c: c.append("ns.left", left_table(1000, 1100, seed=9))
    # right-side overwrite: keys [100, 200) — only the touched right
    # fragment's key range re-joins
    overwrite = lambda c: c.overwrite_range(
        "ns.right", 100, 200, right_table(100, 200, seed=77)
    )

    def expect_feature_add(warm, cold):
        assert warm.rows_to_user_fns > 0
        assert "lz" in warm.outputs["scaled"].column_names

    def expect_code_edit(warm, cold):
        assert warm.node_stats["joined"]["fresh_rows"] == 0
        assert warm.node_stats["scaled"]["fresh_rows"] > 0

    edits = standard_matrix(
        base=dict(hi=499),
        widen=dict(hi=999),
        narrow=dict(hi=299),
        beyond=dict(hi=4999),
        feature_add=dict(hi=4999, columns=("lx", "lz")),
        feature_remove=dict(hi=4999),
        code_edit=dict(hi=4999, gain=2.0),
        append=append,
        overwrite=overwrite,
        expectations={
            # joint residual [500, 1000): 500 left rows + 250 right rows
            "widen": expect_fresh_rows("joined", 750),
            # joint residual [1000, 5000) holds no rows on either side
            "beyond": expect_fresh_rows("joined", 0),
            "feature-add": expect_feature_add,
            "feature-remove": expect_zero_rows,
            # ONLY the left side's appended range: 100 left rows, 0 right —
            # the right side's pins stay valid (labeled per-table)
            "append": expect_fresh_rows("joined", 100),
            # the rewritten right fragment's key stats bound the residual
            "overwrite": expect_fresh_rows_between("joined", 1, 600),
            "code-edit": expect_code_edit,
        },
    )
    sweep(tmp_path, make_workspace, join_project, edits)


# --------------------------------------------------- joint-window intersection
def test_widen_one_side_leaves_joint_window_cached(tmp_path):
    """The joint window is the INTERSECTION of the input windows: widening
    one side's filter without the other does not move it, so the warm run
    is a full hit."""
    ws = make_workspace(str(tmp_path / "lake"))
    first = ws.run(join_project(l_hi=499, r_hi=499))
    res = ws.run(join_project(l_hi=999, r_hi=499))
    assert res.rows_to_user_fns == 0
    assert res.bytes_from_store == 0
    # and the output is literally the narrow join, unchanged
    assert_outputs_bitwise_equal(res, first)

    # widening BOTH sides moves the intersection: residual [500, 1000) only
    res2 = ws.run(join_project(l_hi=999, r_hi=999))
    assert res2.node_stats["joined"]["fresh_rows"] == 750


def test_append_beyond_joint_window_is_noop(tmp_path):
    ws = make_workspace(str(tmp_path / "lake"))
    ws.run(join_project(hi=999))
    ws.catalog.append("ns.right", right_table(2000, 2200, seed=4))
    res = ws.run(join_project(hi=999))  # appended keys sit outside [0, 1000)
    assert res.rows_to_user_fns == 0


def test_join_output_matches_numpy_reference(tmp_path):
    """Cold-run sanity for the join itself (independent of caching): the
    output equals the plain inner join of the two tables."""
    ws = make_workspace(str(tmp_path / "lake"))
    res = ws.run(join_project(hi=999))
    out = res.outputs["joined"]
    lt, rt = left_table(0, 1000), right_table(0, 1000)
    common, li, ri = np.intersect1d(
        lt.column("eventTime"), rt.column("eventTime"), return_indices=True
    )
    np.testing.assert_array_equal(out.column("eventTime"), common)
    np.testing.assert_array_equal(out.column("lx"), np.asarray(lt.column("lx"))[li])
    np.testing.assert_array_equal(out.column("ry"), np.asarray(rt.column("ry"))[ri])
