"""Distribution extras: pipeline parallelism (numerical equality with the
reference stack on a real multi-device mesh), int8 EF gradient compression
(convergence), and the fault-tolerance control loop (failure → rollback →
exact replay)."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.dist.compression import (
    compress_decompress,
    compressed_bytes,
    init_error_state,
    quantize_int8,
    dequantize_int8,
)
from repro.dist.fault import (
    HeartbeatMonitor,
    RestartCoordinator,
    SimClock,
    StragglerDetector,
)


# --------------------------------------------------------------- compression
def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_unbiased_over_time():
    """Sum of EF-compressed gradients ≈ sum of true gradients (the error
    buffer carries the residual forward instead of dropping it)."""
    rng = np.random.default_rng(1)
    params = {"w": jnp.zeros(64)}
    err = init_error_state(params)
    total_true = np.zeros(64)
    total_sent = np.zeros(64)
    for i in range(50):
        g = {"w": jnp.asarray(rng.standard_normal(64) * (1 + i % 5), jnp.float32)}
        sent, err = compress_decompress(g, err)
        total_true += np.asarray(g["w"])
        total_sent += np.asarray(sent["w"])
    resid = np.abs(total_true - total_sent).max()
    # residual is at most one quantization step, NOT 50 accumulated steps
    assert resid < 0.5


def test_ef_sgd_converges_like_uncompressed():
    """Quadratic objective: EF-int8 SGD reaches the optimum."""

    A = jnp.diag(jnp.linspace(1.0, 5.0, 16))
    b = jnp.arange(16.0) / 10

    def grad(w):
        return A @ w - b

    w_star = jnp.linalg.solve(A, b)
    lr = 0.05

    w_plain = jnp.zeros(16)
    w_comp = jnp.zeros(16)
    err = init_error_state({"w": w_comp})
    for _ in range(400):
        w_plain = w_plain - lr * grad(w_plain)
        g, err = compress_decompress({"w": grad(w_comp)}, err)
        w_comp = w_comp - lr * g["w"]
    assert np.linalg.norm(np.asarray(w_plain - w_star)) < 1e-3
    assert np.linalg.norm(np.asarray(w_comp - w_star)) < 1e-2


def test_compressed_bytes_ratio():
    params = {"a": jnp.zeros((128, 128)), "b": jnp.zeros((64,))}
    r = compressed_bytes(params)
    assert r["fp32_bytes"] == 4 * (128 * 128 + 64)
    assert 0.24 < r["ratio"] < 0.27


# ---------------------------------------------------------------- heartbeats
def test_heartbeat_failure_detection():
    clk = SimClock()
    mon = HeartbeatMonitor(["w0", "w1", "w2"], deadline_s=10, clock=clk)
    clk.advance(5)
    mon.beat("w0")
    mon.beat("w1")
    clk.advance(6)  # w2 last beat 11s ago; w0/w1 6s ago
    assert mon.check() == ["w2"]
    assert sorted(mon.alive) == ["w0", "w1"]
    mon.beat("w2")  # zombie beat must not resurrect
    clk.advance(1)
    assert mon.check() == []
    assert "w2" in mon.dead


def test_straggler_robust_zscore():
    det = StragglerDetector(z_threshold=3.0, patience=2)
    flagged = []
    for step in range(6):
        for w in range(8):
            t = 1.0 + 0.01 * w  # healthy spread
            if w == 5 and step >= 2:
                t = 3.0  # w5 becomes 3× slower from step 2
            det.record(f"w{w}", t)
        flagged += det.check()
    assert flagged == ["w5"]


def test_straggler_single_spike_not_flagged():
    det = StragglerDetector(z_threshold=3.0, patience=3)
    for step in range(6):
        for w in range(8):
            t = 1.0 + (2.5 if (w == 3 and step == 2) else 0.01 * w)
            det.record(f"w{w}", t)
        assert det.check() == []


def test_straggler_fleet_wide_slowdown_trips_ewma():
    """All workers degrading together never trips the relative z-score (the
    median moves with the slowdown) — the per-worker EWMA baseline must
    catch it."""
    det = StragglerDetector(z_threshold=3.0, patience=2)
    flagged = []
    for step in range(8):
        for w in range(8):
            t = 1.0 + 0.01 * w  # healthy fleet, learns the baseline
            if step >= 4:
                t *= 3.0  # every worker slows down 3× at step 4
            det.record(f"w{w}", t)
        flagged += det.check()
    assert flagged == [f"w{w}" for w in range(8)]


def test_straggler_ewma_not_poisoned_by_slowdown():
    """A sustained slowdown must not launder itself into the baseline: after
    the fleet degrades, the EWMA stays at the healthy level (only non-slow
    samples feed it), so the slow state keeps striking."""
    det = StragglerDetector(patience=2)
    for step in range(4):
        for w in range(4):
            det.record(f"w{w}", 1.0)
        det.check()
    healthy = det.baseline("w0")
    assert healthy == pytest.approx(1.0)
    for step in range(5):
        for w in range(4):
            det.record(f"w{w}", 3.0)
        det.check()
    assert det.baseline("w0") == pytest.approx(healthy)  # unchanged
    assert set(det.flagged) == {"w0", "w1", "w2", "w3"}


def test_straggler_gradual_drift_within_factor_absorbed():
    """Slow drift under ``slowdown_factor`` per step is absorbed into the
    baseline rather than flagged — the detector targets step changes, not
    capacity planning."""
    det = StragglerDetector(patience=2, slowdown_factor=2.0)
    t = 1.0
    for step in range(10):
        for w in range(4):
            det.record(f"w{w}", t)
        assert det.check() == []
        t *= 1.3  # 30% per-step drift, always under the 2× trigger
    assert det.flagged == []


# ------------------------------------------------- restart coordinator + e2e
def test_failure_rollback_and_exact_replay(tmp_path):
    """Full FT story: train, checkpoint, kill a worker mid-run, roll back,
    replay — final state must equal the never-failed run bit-for-bit."""
    from repro.checkpoint import CheckpointManager, restore_state
    from repro.core.cache import DifferentialCache
    from repro.core.planner import ScanExecutor
    from repro.data import TokenBatchPipeline, write_token_corpus
    from repro.lake.catalog import Catalog
    from repro.lake.s3sim import ObjectStore
    from repro.models.registry import get_config, get_model
    from repro.train.loop import make_init_state, make_train_step
    from repro.train.optimizer import OptimizerConfig

    cfg = get_config("granite-3-2b").reduced()
    api = get_model(cfg)
    opt = OptimizerConfig(kind="adamw", peak_lr=1e-3)
    store = ObjectStore(str(tmp_path / "s3"))
    catalog = Catalog(store, rows_per_fragment=8192)
    write_token_corpus(catalog, "data.c", 12_000, cfg.vocab_size, seed=5)
    scans = ScanExecutor(store, catalog, cache=DifferentialCache())
    pipe = TokenBatchPipeline(scans, "data.c", global_batch=2, seq_len=32, prefetch_depth=0)
    step_fn = jax.jit(make_train_step(api, opt))
    state0 = make_init_state(api, opt)(jax.random.PRNGKey(1))

    # reference: 6 uninterrupted steps
    ref = state0
    for s in range(6):
        ref, _ = step_fn(ref, pipe.batch_at(s))

    # failing run: checkpoint every 2 steps, fail at step 5 (before ckpt 6)
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=2, async_save=False)
    clk = SimClock()
    mon = HeartbeatMonitor(["w0", "w1"], deadline_s=10, clock=clk)
    det = StragglerDetector()

    restored_at = []

    state = state0
    data_step = 0

    def on_restore(step):
        nonlocal state, data_step
        _, plain = mgr.restore(step)
        # rebuild the typed TrainState from the saved tree
        flat = jax.tree_util.tree_leaves(plain)
        state = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(state0), flat
        )
        data_step = step
        restored_at.append(step)

    coord = RestartCoordinator(
        mon, det, latest_checkpoint=mgr.latest, on_restore=on_restore
    )

    failed_once = False
    while data_step < 6:
        # worker heartbeats (w1 stops beating at step 5, first run only)
        clk.advance(1)
        mon.beat("w0")
        if not (data_step == 5 and not failed_once):
            mon.beat("w1")
        else:
            # w1 goes silent past the deadline; w0 keeps beating
            for _ in range(11):
                clk.advance(1)
                mon.beat("w0")
            failed_once = True
            coord.tick(data_step)
            continue  # restart loop body from the restored step
        state, _ = step_fn(state, pipe.batch_at(data_step))
        data_step += 1
        if data_step % 2 == 0:
            mgr.save(data_step, state, extra={"data_step": data_step})

    assert restored_at == [4], "should roll back to the step-4 checkpoint"
    for x, y in zip(jax.tree_util.tree_leaves(ref), jax.tree_util.tree_leaves(state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# --------------------------------------------------------- pipeline parallel
_PP = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.dist.pipeline import pipeline_forward, stack_stage_params

    S_STAGES, L, D = 4, 8, 16
    M, MB, SEQ = 6, 2, 4  # 6 microbatches

    key = jax.random.PRNGKey(0)
    Ws = jax.random.normal(key, (L, D, D)) * (D ** -0.5)

    def layer_fn(x, W):
        return jnp.tanh(x @ W)

    # reference: plain sequential stack
    def ref_stack(x):
        def body(c, W):
            return layer_fn(c, W), None
        out, _ = jax.lax.scan(body, x, Ws)
        return out

    x = jax.random.normal(jax.random.PRNGKey(1), (M, MB, SEQ, D))
    want = jax.vmap(ref_stack)(x)

    mesh = Mesh(np.array(jax.devices()[:4]), ("pp",))
    staged = stack_stage_params({"W": Ws}, S_STAGES)
    staged = jax.device_put(staged, NamedSharding(mesh, P("pp")))

    got = pipeline_forward(
        mesh, lambda c, lp: layer_fn(c, lp["W"]), staged, x, axis="pp"
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)

    # bubble arithmetic: ticks = M + S - 1
    print("PP_OK bubble_fraction=%.3f" % ((S_STAGES - 1) / (M + S_STAGES - 1)))
    """
)


def test_pipeline_parallel_matches_reference():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", _PP],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(__file__)), timeout=600,
    )
    assert "PP_OK" in out.stdout, out.stderr[-3000:]
