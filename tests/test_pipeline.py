"""End-to-end tests for the declarative pipeline layer (paper §II)."""

import numpy as np
import pytest

from repro.core.columnar import Table
from repro.core.intervals import IntervalSet
from repro.pipeline import (
    DagError,
    Model,
    Project,
    Workspace,
    build_dag,
    compile_plan,
    date_ordinal,
    model,
    parse_filter,
    runtime,
)

SCHEMA = {"eventTime": "<i8", "c1": "<f8", "c2": "<f8", "c3": "<i8"}


def events_table(lo, hi, seed=0):
    n = hi - lo
    rng = np.random.default_rng(seed)
    return Table(
        {
            "eventTime": np.arange(lo, hi, dtype=np.int64),
            "c1": rng.standard_normal(n),
            "c2": rng.standard_normal(n),
            "c3": rng.integers(0, 100, n).astype(np.int64),
        }
    )


@pytest.fixture()
def ws(tmp_path):
    w = Workspace(str(tmp_path / "lake"), rows_per_fragment=128)
    w.catalog.create_table("ns", "raw_data", SCHEMA, "eventTime")
    w.catalog.append("ns.raw_data", events_table(0, 1000))
    return w


# ------------------------------------------------------------- filter parser
def test_parse_between_dates():
    f = parse_filter("eventTime BETWEEN 2023-01-01 AND 2023-02-01", "eventTime")
    lo, hi = date_ordinal("2023-01-01"), date_ordinal("2023-02-01")
    assert f.window.to_pairs() == ((lo, hi + 1),)  # SQL BETWEEN is inclusive
    assert not f.predicates


def test_parse_or_union():
    f = parse_filter("eventTime BETWEEN 0 AND 9 OR eventTime BETWEEN 20 AND 29", "eventTime")
    assert f.window.to_pairs() == ((0, 10), (20, 30))


def test_parse_combined_range():
    f = parse_filter("eventTime >= 10 AND eventTime < 20", "eventTime")
    assert f.window.to_pairs() == ((10, 20),)


def test_parse_post_predicate():
    f = parse_filter("eventTime BETWEEN 0 AND 99 AND c3 >= 50", "eventTime")
    assert f.window.to_pairs() == ((0, 100),)
    assert f.predicates == [("c3", ">=", 50)]
    assert f.predicate_columns == ("c3",)


def test_parse_rejects_or_over_predicates():
    with pytest.raises(ValueError):
        parse_filter("c3 >= 50 OR eventTime < 10", "eventTime")


def test_parse_rejects_garbage():
    with pytest.raises(ValueError):
        parse_filter("eventTime BETWEEN AND 10", "eventTime")


# ------------------------------------------------------------------ DAG build
def paper_listing1_project() -> Project:
    """The paper's Listing 1 DAG: raw_data -> cleaned_data -> final_data ->
    training_data, with two runtimes standing in for two interpreters."""
    p = Project("listing1")

    @model(project=p)
    @runtime("numpy")
    def cleaned_data(
        data=Model(
            "ns.raw_data",
            columns=["c1", "c2", "c3"],
            filter="eventTime BETWEEN 0 AND 309",
        )
    ):
        keep = ~np.isnan(data.column("c1"))
        return data.filter(keep)

    @model(project=p)
    @runtime("numpy")
    def final_data(data=Model("cleaned_data")):
        return {
            "c1": data.column("c1"),
            "c13": data.column("c1") + data.column("c3"),
        }

    @model(project=p)
    @runtime("jax")
    def training_data(data=Model("final_data")):
        import jax.numpy as jnp

        return {"feature": (data["c13"] - jnp.mean(data["c13"])) / (jnp.std(data["c13"]) + 1e-6)}

    return p


def test_dag_reconstruction_from_inputs(ws):
    p = paper_listing1_project()
    dag = build_dag(p)
    assert dag.order == ["cleaned_data", "final_data", "training_data"]
    assert dag.edges["training_data"] == ["final_data"]
    assert dag.scan_leaves["cleaned_data"][0][1].name == "ns.raw_data"
    assert dag.sinks() == ["training_data"]


def test_dag_cycle_detection():
    p = Project("cyclic")

    @model(project=p)
    def a(x=Model("b")):
        return x

    @model(project=p)
    def b(x=Model("a")):
        return x

    with pytest.raises(DagError, match="cycle"):
        build_dag(p)


def test_dag_unknown_ref():
    p = Project("bad")

    @model(project=p)
    def a(x=Model("nonexistent_model")):
        return x

    with pytest.raises(DagError, match="unknown reference"):
        build_dag(p)


def test_filters_on_model_edges_rejected():
    p = Project("bad2")

    @model(project=p)
    def a(x=Model("ns.t", columns=["c1"])):
        return x

    @model(project=p)
    def b(x=Model("a", columns=["c1"])):
        return x

    with pytest.raises(DagError, match="scan leaves"):
        build_dag(p)


def test_physical_plan_inserts_system_scan(ws):
    p = paper_listing1_project()
    dag = build_dag(p)
    plan = compile_plan(dag, {"ns.raw_data": "eventTime"})
    assert len(plan.scans) == 1
    s = plan.scans[0]
    assert s.table == "ns.raw_data"
    assert s.columns == ("c1", "c2", "c3")
    assert s.window_pairs == ((0, 310),)
    # describe() is the human-readable plan
    assert "SCAN ns.raw_data" in plan.describe()
    assert "RUN [jax] training_data" in plan.describe()


# ----------------------------------------------------------------- execution
def test_run_listing1_end_to_end(ws):
    p = paper_listing1_project()
    res = ws.run(p)
    assert set(res.outputs) == {"cleaned_data", "final_data", "training_data"}
    feat = res.outputs["training_data"].column("feature")
    assert feat.shape[0] == 310
    assert abs(float(np.mean(feat))) < 1e-3  # normalized
    assert res.bytes_from_store > 0


def test_rerun_hits_cache_across_languages(ws):
    p = paper_listing1_project()
    r1 = ws.run(p)
    r2 = ws.run(p)
    assert r2.bytes_from_store == 0, "second run must be served from cache"
    assert r2.bytes_from_cache > 0
    np.testing.assert_allclose(
        r1.outputs["training_data"].column("feature"),
        r2.outputs["training_data"].column("feature"),
    )


def test_materialize_publishes_table(ws):
    p = Project("mat")

    @model(project=p, materialize=True)
    def snapshot_model(
        data=Model("ns.raw_data", columns=["c1"], filter="eventTime BETWEEN 0 AND 99")
    ):
        return {"eventTime": np.arange(100, dtype=np.int64), "c1": data.column("c1")}

    ws.run(p)
    snap = ws.catalog.current_snapshot("models.snapshot_model")
    assert sum(f.row_count for f in snap.fragments) == 100
    # downstream project can scan the materialized model
    p2 = Project("consumer")

    @model(project=p2)
    def reader(d=Model("models.snapshot_model", columns=["c1"], filter="eventTime BETWEEN 0 AND 49")):
        return d

    res = ws.run(p2)
    assert res.outputs["reader"].num_rows == 50


def test_time_travel_scan(ws):
    old = ws.catalog.current_snapshot("ns.raw_data").snapshot_id
    ws.catalog.append("ns.raw_data", events_table(1000, 1100, seed=7))
    p = Project("tt")

    @model(project=p)
    def now(d=Model("ns.raw_data", columns=["c1"])):
        return d

    @model(project=p)
    def friday(d=Model("ns.raw_data", columns=["c1"], snapshot_id=old)):
        return d

    res = ws.run(p)
    assert res.outputs["now"].num_rows == 1100
    assert res.outputs["friday"].num_rows == 1000  # last Friday's rows


def test_post_predicate_in_pipeline(ws):
    p = Project("pred")

    @model(project=p)
    def evens(
        d=Model(
            "ns.raw_data",
            columns=["c3"],
            filter="eventTime BETWEEN 0 AND 99 AND c3 >= 50",
        )
    ):
        return d

    res = ws.run(p)
    assert np.all(res.outputs["evens"].column("c3") >= 50)
