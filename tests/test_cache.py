"""Correctness tests for the differential cache (paper §III).

The central invariant: for ANY sequence of scans against ANY snapshot
history, a scan served through the differential cache returns exactly the
same multiset of rows as an uncached scan — while reading no more bytes from
object storage than the uncached path, and strictly fewer when windows
overlap.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.baselines import NoCache, ScanCache
from repro.core.cache import DifferentialCache
from repro.core.columnar import ChunkedTable, Table
from repro.core.intervals import IntervalSet
from repro.core.planner import ResultCachingExecutor, ScanExecutor
from repro.lake.catalog import Catalog
from repro.lake.s3sim import ObjectStore

SCHEMA = {"eventTime": "<i8", "c1": "<f8", "c2": "<f8", "c3": "<i8"}


def events_table(lo, hi, seed=0):
    n = hi - lo
    rng = np.random.default_rng(seed + lo)
    return Table(
        {
            "eventTime": np.arange(lo, hi, dtype=np.int64),
            "c1": rng.standard_normal(n),
            "c2": rng.standard_normal(n),
            "c3": rng.integers(0, 100, n).astype(np.int64),
        }
    )


@pytest.fixture()
def env(tmp_path):
    store = ObjectStore(str(tmp_path / "s3"))
    catalog = Catalog(store, rows_per_fragment=64)
    catalog.create_table("ns", "raw", SCHEMA, "eventTime")
    catalog.append("ns.raw", events_table(0, 1000))
    return store, catalog


def rows_of(chunked, cols):
    t = chunked.combine()
    if t.num_rows == 0:
        return set()
    return set(zip(*[t.column(c).tolist() for c in cols]))


def reference_rows(store, catalog, cols, window):
    ex = ScanExecutor(store, catalog, cache=NoCache())
    return rows_of(ex.scan("ns.raw", cols, window), cols)


# --------------------------------------------------------------- §III-A flow
def test_paper_section3a_workload(env):
    """Users A, B, A′ from §III-A — the motivating example, verbatim."""
    store, catalog = env
    ex = ScanExecutor(store, catalog, cache=DifferentialCache())

    # (1) user A: c1,c2,c3 over Jan (here keys [0, 310))
    before = store.stats.bytes_read
    ex.scan("ns.raw", ["c1", "c2", "c3"], IntervalSet.of((0, 310)))
    bytes_a = store.stats.bytes_read - before
    assert bytes_a > 0

    # (2) user B: c1,c3 over Jan..Feb ([0, 620)) — only Feb should be fetched
    before = store.stats.bytes_read
    out_b = ex.scan("ns.raw", ["c1", "c3"], IntervalSet.of((0, 620)))
    bytes_b = store.stats.bytes_read - before
    assert bytes_b > 0
    assert bytes_b < bytes_a  # differential: roughly the Feb half, 2 cols
    assert rows_of(out_b, ["c1", "c3"]) == reference_rows(store, catalog, ["c1", "c3"], IntervalSet.of((0, 620)))

    # (3) user A again: c2 only, one day ([0, 10)) — zero object-store reads
    before = store.stats.bytes_read
    out_a2 = ex.scan("ns.raw", ["c2"], IntervalSet.of((0, 10)))
    assert store.stats.bytes_read == before, "request #3 requires no scan (paper Fig. 4)"
    assert rows_of(out_a2, ["c2"]) == reference_rows(store, catalog, ["c2"], IntervalSet.of((0, 10)))


def test_exact_repeat_is_free(env):
    store, catalog = env
    ex = ScanExecutor(store, catalog, cache=DifferentialCache())
    w = IntervalSet.of((100, 300))
    ex.scan("ns.raw", ["c1"], w)
    before = store.stats.bytes_read
    out = ex.scan("ns.raw", ["c1"], w)
    assert store.stats.bytes_read == before
    assert rows_of(out, ["c1"]) == reference_rows(store, catalog, ["c1"], w)


def test_superset_projection_serves_subset(env):
    store, catalog = env
    ex = ScanExecutor(store, catalog, cache=DifferentialCache())
    ex.scan("ns.raw", ["c1", "c2", "c3"], IntervalSet.of((0, 200)))
    before = store.stats.bytes_read
    out = ex.scan("ns.raw", ["c3"], IntervalSet.of((50, 150)))
    assert store.stats.bytes_read == before
    assert rows_of(out, ["c3"]) == reference_rows(store, catalog, ["c3"], IntervalSet.of((50, 150)))


def test_subset_projection_does_not_serve_superset(env):
    store, catalog = env
    ex = ScanExecutor(store, catalog, cache=DifferentialCache())
    ex.scan("ns.raw", ["c1"], IntervalSet.of((0, 200)))
    before = store.stats.bytes_read
    out = ex.scan("ns.raw", ["c1", "c2"], IntervalSet.of((0, 200)))
    assert store.stats.bytes_read > before  # must re-fetch: c2 missing
    assert rows_of(out, ["c1", "c2"]) == reference_rows(store, catalog, ["c1", "c2"], IntervalSet.of((0, 200)))


def test_adjacent_windows_merge_into_one_element(env):
    store, catalog = env
    cache = DifferentialCache()
    ex = ScanExecutor(store, catalog, cache=cache)
    ex.scan("ns.raw", ["c1"], IntervalSet.of((0, 128)))
    ex.scan("ns.raw", ["c1"], IntervalSet.of((128, 256)))
    elems = cache.elements("ns.raw")
    assert len(elems) == 1  # merged (overlapping/adjacent combine, §III-B)
    assert elems[0].window.to_pairs() == ((0, 256),)


def test_disjoint_windows_covered_after_gap_fill(env):
    store, catalog = env
    ex = ScanExecutor(store, catalog, cache=DifferentialCache())
    ex.scan("ns.raw", ["c1"], IntervalSet.of((0, 100)))
    ex.scan("ns.raw", ["c1"], IntervalSet.of((400, 500)))
    # spanning scan: only the gap [100,400) should be fetched
    before = store.stats.bytes_read
    out = ex.scan("ns.raw", ["c1"], IntervalSet.of((0, 500)))
    gap_only = store.stats.bytes_read - before
    assert gap_only > 0
    ex2 = ScanExecutor(store, catalog, cache=NoCache())
    before = store.stats.bytes_read
    ex2.scan("ns.raw", ["c1"], IntervalSet.of((0, 500)))
    full = store.stats.bytes_read - before
    assert gap_only < full
    assert rows_of(out, ["c1"]) == reference_rows(store, catalog, ["c1"], IntervalSet.of((0, 500)))


def test_cache_serves_views_zero_copy(env):
    store, catalog = env
    cache = DifferentialCache()
    ex = ScanExecutor(store, catalog, cache=cache)
    ex.scan("ns.raw", ["c1"], IntervalSet.of((0, 320)))
    out = ex.scan("ns.raw", ["c1"], IntervalSet.of((10, 300)))
    elem = cache.elements("ns.raw")[0]
    assert any(
        np.shares_memory(chunk.column("c1"), elem.data.column("c1"))
        for chunk in out.chunks
    ), "cache hits must be zero-copy views over the element buffer"


def test_invalidation_on_overwrite(env):
    store, catalog = env
    ex = ScanExecutor(store, catalog, cache=DifferentialCache())
    ex.scan("ns.raw", ["c1"], IntervalSet.of((0, 1000)))
    # mutate part of the table: delete keys [0, 128)
    catalog.overwrite_range("ns.raw", 0, 128)
    out = ex.scan("ns.raw", ["c1"], IntervalSet.of((0, 1000)))
    assert rows_of(out, ["c1"]) == reference_rows(store, catalog, ["c1"], IntervalSet.of((0, 1000)))


def test_differential_invalidation_is_partial(env):
    """Beyond-paper: untouched windows survive a mutation elsewhere."""
    store, catalog = env
    ex = ScanExecutor(store, catalog, cache=DifferentialCache())
    ex.scan("ns.raw", ["c1"], IntervalSet.of((0, 1000)))
    catalog.overwrite_range("ns.raw", 900, 1000)  # touch only the tail
    before = store.stats.bytes_read
    out = ex.scan("ns.raw", ["c1"], IntervalSet.of((0, 256)))
    assert store.stats.bytes_read == before, "untouched window must stay cached"
    assert rows_of(out, ["c1"]) == reference_rows(store, catalog, ["c1"], IntervalSet.of((0, 256)))


def test_append_extends_validity(env):
    store, catalog = env
    ex = ScanExecutor(store, catalog, cache=DifferentialCache())
    ex.scan("ns.raw", ["c1"], IntervalSet.of((0, 500)))
    catalog.append("ns.raw", events_table(1000, 1200))
    before = store.stats.bytes_read
    out = ex.scan("ns.raw", ["c1"], IntervalSet.of((0, 500)))
    assert store.stats.bytes_read == before  # append outside window: still valid
    assert rows_of(out, ["c1"]) == reference_rows(store, catalog, ["c1"], IntervalSet.of((0, 500)))


def test_eviction_under_budget(env):
    store, catalog = env
    cache = DifferentialCache(max_bytes=20_000)
    ex = ScanExecutor(store, catalog, cache=cache)
    for lo in range(0, 1000, 100):
        ex.scan("ns.raw", ["c1", "c2", "c3"], IntervalSet.of((lo, lo + 100)))
    assert cache.nbytes <= 20_000
    assert cache.evictions > 0
    # correctness survives eviction
    out = ex.scan("ns.raw", ["c1"], IntervalSet.of((0, 1000)))
    assert rows_of(out, ["c1"]) == reference_rows(store, catalog, ["c1"], IntervalSet.of((0, 1000)))


def test_warm_vs_cold_residual_is_delta_only(env):
    """Paper §III / Table 2 behavior: a repeated scan's residual fetch is 0
    bytes, and widening the time window fetches exactly the delta — the same
    bytes an uncached executor reads for the delta window alone."""
    store, catalog = env
    ex = ScanExecutor(store, catalog, cache=DifferentialCache())
    cols = ["c1", "c2"]
    w = IntervalSet.of((0, 256))

    # cold scan populates the cache
    ex.scan("ns.raw", cols, w)
    assert ex.reports[-1].bytes_from_store > 0

    # warm repeat: the plan's residual fetch is 0 bytes, all from cache
    before = store.stats.bytes_read
    ex.scan("ns.raw", cols, w)
    warm = ex.reports[-1]
    assert store.stats.bytes_read == before
    assert warm.bytes_from_store == 0 and warm.store_requests == 0
    assert warm.fully_cached and warm.bytes_from_cache > 0

    # widen the window: fetched bytes == the delta only
    ex.scan("ns.raw", cols, IntervalSet.of((0, 512)))
    widened = ex.reports[-1]
    cold = ScanExecutor(store, catalog, cache=NoCache())
    cold.scan("ns.raw", cols, IntervalSet.of((256, 512)))
    delta_bytes = cold.reports[-1].bytes_from_store
    assert widened.bytes_from_store == delta_bytes > 0
    assert rows_of(
        ex.scan("ns.raw", cols, IntervalSet.of((0, 512))), cols
    ) == reference_rows(store, catalog, cols, IntervalSet.of((0, 512)))


def test_scan_cache_baseline_exact_match_only(env):
    store, catalog = env
    ex = ScanExecutor(store, catalog, cache=ScanCache())
    w = IntervalSet.of((0, 200))
    ex.scan("ns.raw", ["c1"], w)
    before = store.stats.bytes_read
    ex.scan("ns.raw", ["c1"], w)  # exact repeat: hit
    assert store.stats.bytes_read == before
    ex.scan("ns.raw", ["c1"], IntervalSet.of((0, 199)))  # overlap: miss
    assert store.stats.bytes_read > before


def test_result_cache_baseline(env):
    store, catalog = env
    ex = ResultCachingExecutor(store, catalog)
    w = IntervalSet.of((0, 200))
    ex.scan("ns.raw", ["c1"], w)
    before = store.stats.bytes_read
    ex.scan("ns.raw", ["c1"], w)
    assert store.stats.bytes_read == before
    assert ex.hits == 1


def test_predicate_post_filter(env):
    store, catalog = env
    ex = ScanExecutor(store, catalog, cache=DifferentialCache())
    pred = lambda t: t.column("c3") % 2 == 0
    out = ex.scan("ns.raw", ["c3"], IntervalSet.of((0, 100)), predicate=pred)
    vals = out.combine().column("c3")
    assert np.all(vals % 2 == 0)
    # predicate doesn't poison the cache: unfiltered scan still correct
    out2 = ex.scan("ns.raw", ["c3"], IntervalSet.of((0, 100)))
    assert rows_of(out2, ["c3"]) == reference_rows(store, catalog, ["c3"], IntervalSet.of((0, 100)))


# ------------------------------------------------- cross-snapshot merging
def test_merge_respects_snapshots_out_of_order_append(env):
    """Elements cached under different snapshots may only merge their
    *usable* windows: an element predating an out-of-order append must not
    donate its (now row-incomplete) window to a merged element whose pins
    include the new fragment — that made the missing rows look valid."""
    store, catalog = env
    cache = DifferentialCache()
    ex = ScanExecutor(store, catalog, cache=cache)
    ex.scan("ns.raw", ["c1"], IntervalSet.of((0, 128)))  # E1 @ snapshot 1

    # out-of-order append: NEW rows whose keys land inside E1's window
    catalog.append(
        "ns.raw",
        Table(
            {
                "eventTime": np.arange(50, 60, dtype=np.int64),
                "c1": np.arange(10, dtype=np.float64) + 5000.0,
                "c2": np.zeros(10),
                "c3": np.zeros(10, dtype=np.int64),
            }
        ),
    )
    # overlapping scan under snapshot 2: fetches the residual (which pins
    # the new fragment) and merges it with E1
    ex.scan("ns.raw", ["c1"], IntervalSet.of((32, 256)))

    got = rows_of(ex.scan("ns.raw", ["c1"], IntervalSet.of((0, 256))), ["c1"])
    want = reference_rows(store, catalog, ["c1"], IntervalSet.of((0, 256)))
    assert got == want, "merged element must include the appended rows"


def test_merge_after_overwrite_drops_stale_rows(env):
    """After an overwrite, merging an old element with a fresh one must not
    carry the old element's dropped-fragment rows into the merged data."""
    store, catalog = env
    cache = DifferentialCache()
    ex = ScanExecutor(store, catalog, cache=cache)
    ex.scan("ns.raw", ["c1"], IntervalSet.of((0, 128)))  # E1 @ snapshot 1

    catalog.overwrite_range(
        "ns.raw",
        0,
        64,
        Table(
            {
                "eventTime": np.arange(0, 64, dtype=np.int64),
                "c1": -(np.arange(64, dtype=np.float64) + 1000.0),
                "c2": np.zeros(64),
                "c3": np.zeros(64, dtype=np.int64),
            }
        ),
    )
    ex.scan("ns.raw", ["c1"], IntervalSet.of((0, 256)))  # residual + merge

    # every element must reproduce the reference rows over its FULL claimed
    # window — stale rows inside merged data fail this even when the serving
    # path happens to mask them
    cols = ["c1", "eventTime"]
    for e in cache.elements("ns.raw"):
        chunks = e.slice_window(e.window, cols)
        got = rows_of(ChunkedTable(chunks), cols) if chunks else set()
        want = reference_rows(store, catalog, cols, e.window)
        assert got == want
    got = rows_of(ex.scan("ns.raw", ["c1"], IntervalSet.of((0, 256))), ["c1"])
    assert got == reference_rows(store, catalog, ["c1"], IntervalSet.of((0, 256)))


# --------------------------------------------------------- property testing
window_strategy = st.tuples(st.integers(0, 1000), st.integers(0, 1000)).map(
    lambda p: (min(p), max(p) + 1)
)
cols_strategy = st.sets(st.sampled_from(["c1", "c2", "c3"]), min_size=1).map(sorted)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(cols_strategy, window_strategy), min_size=1, max_size=8))
def test_property_any_scan_sequence_is_correct(scans):
    """For any scan sequence: differential output == uncached output, and
    cumulative bytes read never exceed the uncached path's."""
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        store = ObjectStore(d + "/s3")
        catalog = Catalog(store, rows_per_fragment=128)
        catalog.create_table("ns", "raw", SCHEMA, "eventTime")
        catalog.append("ns.raw", events_table(0, 1000))
        baseline_start = store.stats.bytes_read

        cached = ScanExecutor(store, catalog, cache=DifferentialCache())
        uncached = ScanExecutor(store, catalog, cache=NoCache())

        cached_bytes = 0
        uncached_bytes = 0
        for cols, (lo, hi) in scans:
            w = IntervalSet.of((lo, hi))
            b0 = store.stats.bytes_read
            got = rows_of(cached.scan("ns.raw", cols, w), cols)
            cached_bytes += store.stats.bytes_read - b0
            b0 = store.stats.bytes_read
            want = rows_of(uncached.scan("ns.raw", cols, w), cols)
            uncached_bytes += store.stats.bytes_read - b0
            assert got == want
        assert cached_bytes <= uncached_bytes


@settings(max_examples=15, deadline=None)
@given(
    st.lists(st.tuples(cols_strategy, window_strategy), min_size=1, max_size=5),
    st.lists(st.tuples(window_strategy, st.booleans()), min_size=1, max_size=3),
)
def test_property_correct_across_mutations(scans, mutations):
    """Scans interleaved with appends/overwrites stay correct (invalidation)."""
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        store = ObjectStore(d + "/s3")
        catalog = Catalog(store, rows_per_fragment=128)
        catalog.create_table("ns", "raw", SCHEMA, "eventTime")
        catalog.append("ns.raw", events_table(0, 500))
        cached = ScanExecutor(store, catalog, cache=DifferentialCache())
        uncached = ScanExecutor(store, catalog, cache=NoCache())

        ops = [("scan", s) for s in scans] + [("mut", m) for m in mutations]
        # deterministic interleave
        ops.sort(key=lambda o: hash(str(o)) % 1000)
        next_key = 2000
        for kind, payload in ops:
            if kind == "scan":
                cols, (lo, hi) = payload
                w = IntervalSet.of((lo, hi))
                got = rows_of(cached.scan("ns.raw", cols, w), cols)
                want = rows_of(uncached.scan("ns.raw", cols, w), cols)
                assert got == want
            else:
                (lo, hi), is_append = payload
                if is_append:
                    catalog.append("ns.raw", events_table(next_key, next_key + 50))
                    next_key += 50
                else:
                    catalog.overwrite_range("ns.raw", lo, hi)
