"""Test bootstrap: make ``python -m pytest -q`` work from the repo root.

- Prepends ``src/`` to ``sys.path`` so ``import repro`` works without the
  ``PYTHONPATH=src`` incantation (which keeps working too — duplicate path
  entries are harmless).
- Installs the deterministic hypothesis stand-in when the real package is
  not available (this container cannot pip-install).
"""

import os
import sys

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

# repo root too, so tests can drive the benchmark workloads (BENCH_3 asserts
# the incremental-engine acceptance ratios on the same loop CI smokes)
_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
if _ROOT not in sys.path:
    sys.path.insert(1, _ROOT)

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import _hypothesis_stub

    _hypothesis_stub.install()
