"""Per-architecture smoke tests: a REDUCED config of each family runs one
forward + one train-ish step (loss + grads) on CPU, asserting output shapes
and the absence of NaNs.  Full configs are exercised only via the dry-run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ARCH_IDS, SHAPES, get_config, get_model, input_specs, cell_is_runnable
from repro.models.layers import cross_entropy

B, S = 2, 64


def _toy_batch(cfg, key):
    kt, kp = jax.random.split(key)
    tokens = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
    prefix = None
    if cfg.frontend != "none":
        prefix = jax.random.normal(kp, (B, cfg.prefix_len, cfg.d_model), jnp.float32)
    return tokens, prefix


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_shapes_and_finite(arch_id):
    cfg = get_config(arch_id).reduced()
    api = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init_params(key)
    tokens, prefix = _toy_batch(cfg, key)
    logits = jax.jit(api.forward)(params, tokens, prefix)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch_id}: non-finite logits"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_step_grads_finite(arch_id):
    cfg = get_config(arch_id).reduced()
    api = get_model(cfg)
    key = jax.random.PRNGKey(1)
    params = api.init_params(key)
    tokens, prefix = _toy_batch(cfg, key)
    labels = jnp.roll(tokens, -1, axis=1)
    mask = jnp.ones((B, S), jnp.float32)

    def loss_fn(p):
        logits = api.forward(p, tokens, prefix)
        loss, _ = cross_entropy(logits, labels, mask)
        return loss

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss))
    # loss should be near ln(V) at random init (sanity on the loss scale)
    assert float(loss) < np.log(cfg.vocab_size) * 2.0
    leaves = jax.tree.leaves(grads)
    assert leaves, "no gradients produced"
    for g in leaves:
        assert bool(jnp.all(jnp.isfinite(g))), f"{arch_id}: non-finite grad"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_prefill_decode_matches_forward(arch_id):
    """Teacher-forcing equivalence: prefill(t[:k]) then decode steps must
    reproduce forward()'s logits — the serving path's correctness oracle."""
    cfg = get_config(arch_id).reduced()
    api = get_model(cfg)
    key = jax.random.PRNGKey(2)
    params = api.init_params(key)
    tokens, prefix = _toy_batch(cfg, key)
    k = S // 2

    full_logits = jax.jit(api.forward)(params, tokens, prefix)
    last, cache = jax.jit(lambda p, t, pe: api.prefill(p, t, pe, max_len=S))(
        params, tokens[:, :k], prefix
    )
    np.testing.assert_allclose(
        np.asarray(last[:, 0]), np.asarray(full_logits[:, k - 1]), rtol=2e-2, atol=2e-2
    )
    step = jax.jit(api.decode_step)
    for i in range(k, min(k + 4, S)):
        logits, cache = step(params, tokens[:, i : i + 1], cache)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]),
            np.asarray(full_logits[:, i]),
            rtol=2e-2,
            atol=2e-2,
            err_msg=f"{arch_id}: decode step {i} diverged from forward",
        )


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_input_specs_cover_all_cells(arch_id):
    cfg = get_config(arch_id)
    for shape in SHAPES.values():
        ok, reason = cell_is_runnable(cfg, shape)
        if not ok:
            assert shape.name == "long_500k" and not cfg.is_subquadratic
            continue
        specs = input_specs(cfg, shape)
        assert "tokens" in specs
        if shape.kind == "train":
            assert specs["tokens"].shape == (shape.global_batch, shape.seq_len)
            assert "labels" in specs and "loss_mask" in specs
        if shape.kind == "decode":
            assert specs["tokens"].shape == (shape.global_batch, 1)
            assert "cache" in specs
        if cfg.frontend != "none" and shape.kind in ("train", "prefill"):
            assert specs["prefix_embeds"].shape[1] == cfg.prefix_len


def test_param_counts_are_plausible():
    """Analytic param counts should be within ~20% of the advertised sizes
    (for archs whose name encodes one)."""
    expected = {
        "nemotron-4-340b": 340e9,
        "phi3-mini-3.8b": 3.8e9,
        "granite-3-2b": 2.5e9,
        "granite-3-8b": 8.1e9,
        "mamba2-780m": 0.78e9,
        "zamba2-1.2b": 1.2e9,
    }
    for arch, want in expected.items():
        got = get_config(arch).param_count()
        assert 0.7 * want < got < 1.45 * want, f"{arch}: {got:.2e} vs {want:.2e}"
    # MoE: total vs active
    mix = get_config("mixtral-8x22b")
    assert mix.param_count() > 120e9  # ~141B total
    assert mix.active_param_count() < 50e9  # ~39B active


def test_long_context_rule():
    quadratic = [a for a in ARCH_IDS if not get_config(a).is_subquadratic]
    assert set(quadratic) == {
        "musicgen-medium",
        "nemotron-4-340b",
        "phi3-mini-3.8b",
        "granite-3-2b",
        "granite-3-8b",
        "internvl2-76b",
        "llama4-scout-17b-a16e",
    }
