"""In-flight residual coalescing (ISSUE 5): when N concurrent runs plan the
same ``(signature, window)`` residual, exactly one computes it — the rest
subscribe to its claim, replan after the insert, and are served as hits.
"""

import threading
import time

import numpy as np

from repro.core.intervals import Interval, IntervalSet
from repro.pipeline import Model, Project, model, runtime
from repro.service import PipelineService, SharedStore

from test_service import (
    assert_outputs_bitwise_equal,
    cold_reference,
    write_events,
)

# read by name from the flaky model fn below: module globals do not enter
# code_fingerprint, so mutating this cannot change the node's signature
_BOOM = []


# ------------------------------------------------------------- claim API unit
def test_claim_is_exclusive_and_wakes_subscribers():
    store = SharedStore()
    win = IntervalSet([Interval(0, 100)])
    claim, ev = store.claim_residual("sig", win)
    assert claim is not None and ev is None

    got = {}
    subscribed = threading.Event()

    def subscriber():
        c, e = store.claim_residual("sig", IntervalSet([Interval(50, 150)]))
        got["claim"], got["event"] = c, e
        subscribed.set()
        if e is not None:
            got["woken"] = e.wait(5)

    t = threading.Thread(target=subscriber)
    t.start()
    assert subscribed.wait(5)
    assert got["claim"] is None and got["event"] is not None
    store.release_residual(claim)
    t.join(5)
    assert got["woken"] is True
    assert store.coalesced_waits == 1


def test_same_thread_never_waits_on_its_own_claim():
    store = SharedStore()
    win = IntervalSet([Interval(0, 100)])
    c1, _ = store.claim_residual("sig", win)
    c2, ev = store.claim_residual("sig", win)  # same thread: owns a new claim
    assert c1 is not None and c2 is not None and ev is None
    store.release_residual(c1)
    store.release_residual(c2)


def test_column_superset_rule():
    """A scan residual only coalesces onto a claim whose columns cover its
    own — waiting on a narrower in-flight scan would replan forever."""
    store = SharedStore()
    win = IntervalSet([Interval(0, 100)])
    claim, _ = store.claim_residual("t", win, columns=("a", "b"))

    def probe(cols, out):
        out.append(store.claim_residual("t", win, columns=cols))

    narrow, wide = [], []
    t1 = threading.Thread(target=probe, args=(("a",), narrow))
    t2 = threading.Thread(target=probe, args=(("a", "b", "c"), wide))
    t1.start(); t1.join()
    t2.start(); t2.join()
    assert narrow[0][0] is None, "covered columns subscribe"
    assert wide[0][0] is not None, "uncovered columns claim their own"
    store.release_residual(claim)
    store.release_residual(wide[0][0])


def test_disjoint_windows_do_not_coalesce():
    store = SharedStore()
    c1, _ = store.claim_residual("sig", IntervalSet([Interval(0, 100)]))
    out = []
    t = threading.Thread(
        target=lambda: out.append(
            store.claim_residual("sig", IntervalSet([Interval(200, 300)]))
        )
    )
    t.start(); t.join()
    assert out[0][0] is not None, "disjoint residuals run concurrently"
    store.release_residual(c1)
    store.release_residual(out[0][0])


def test_coalesce_off_is_a_noop():
    """With coalescing disabled, claim_residual registers nothing and
    callers proceed immediately — no claim bookkeeping on the hot path."""
    store = SharedStore(coalesce=False)
    win = IntervalSet([Interval(0, 100)])
    assert store.claim_residual("sig", win) == (None, None)
    assert store._claims == {}
    assert store.coalesced_waits == 0


def test_claim_kinds_do_not_cross_coalesce():
    """A keyed residual and a rowwise one can collide on ``(signature,
    window)`` while their windows live in different coordinate spaces (key
    groups vs row keys) — claims must only coalesce within one kind.
    Regression for the pre-``kind`` claim key: a keyed run would subscribe
    to the rowwise claim and wait for an insert it can never use."""
    store = SharedStore()
    win = IntervalSet([Interval(0, 100)])
    c1, _ = store.claim_residual("sig", win, kind="rowwise")

    crossed, same = [], []
    t1 = threading.Thread(
        target=lambda: crossed.append(store.claim_residual("sig", win, kind="keyed"))
    )
    t1.start(); t1.join()
    assert crossed[0][0] is not None, "different kinds must claim their own"
    assert store.coalesced_waits == 0

    t2 = threading.Thread(
        target=lambda: same.append(store.claim_residual("sig", win, kind="rowwise"))
    )
    t2.start(); t2.join()
    assert same[0][0] is None and same[0][1] is not None, "same kind coalesces"
    store.release_residual(c1)
    store.release_residual(crossed[0][0])


def test_snapshot_mismatch_does_not_subscribe():
    """A subscriber pinned to a different snapshot would fail the owner's
    rows' fragment-pin check anyway — it must claim its own residual
    instead of waiting for an unusable insert."""
    store = SharedStore()
    win = IntervalSet([Interval(0, 100)])
    c1, _ = store.claim_residual("sig", win, snapshot_id="snap-a")
    out = []
    t = threading.Thread(
        target=lambda: out.append(
            store.claim_residual("sig", win, snapshot_id="snap-b")
        )
    )
    t.start(); t.join()
    assert out[0][0] is not None, "different snapshot: own claim, no wait"
    assert store.coalesced_waits == 0
    store.release_residual(c1)
    store.release_residual(out[0][0])


# ----------------------------------------------------- service-level behavior
def slow_project(hi, delay=0.3):
    """Same shape as test_service.pipeline_project but each stage sleeps, so
    two concurrent runs reliably overlap in their residual computations."""
    p = Project("coal")

    @model(project=p, incremental="rowwise")
    @runtime("numpy")
    def cleaned(
        data=Model("ns.events", columns=["v1", "v2", "flag"],
                   filter=f"eventTime BETWEEN 0 AND {hi}")
    ):
        time.sleep(delay)
        return data.filter(data.column("flag") > 0)

    @model(project=p, incremental="rowwise")
    @runtime("numpy")
    def scored(data=Model("cleaned")):
        time.sleep(delay)
        out = {n: data.column(n) for n in data.column_names}
        out["score"] = (
            np.asarray(data.column("v1"), np.float64)
            + np.asarray(data.column("v2"), np.float64)
        )
        return out

    return p


def test_concurrent_identical_runs_compute_residual_exactly_once(tmp_path):
    """The BENCH_4 duplicate-work hole: N concurrent tenants running the
    identical pipeline must execute the residual user fns exactly once —
    total rows_to_user_fns across ALL runs equals one cold run's."""
    rows = 1000
    with PipelineService(
        str(tmp_path / "svc"), workers=3, rows_per_fragment=256
    ) as svc:
        write_events(svc.catalog, 0, rows)
        project = slow_project(hi=rows - 1)
        handles = [
            svc.submit(t, project) for t in ("alice", "bob", "carol")
        ]
        svc.drain(60)
        for h in handles:
            assert h.state == "DONE", h.error
        total_rows = sum(h.result.rows_to_user_fns for h in handles)
        waits = svc.model_store.coalesced_waits + svc.scan_cache.coalesced_waits

    ref = cold_reference(tmp_path, "coal-ref", slow_project(hi=rows - 1), rows=rows)
    assert total_rows == ref.rows_to_user_fns, (
        f"duplicate residual work: {total_rows} rows vs {ref.rows_to_user_fns} once"
    )
    assert waits >= 1, "the losers subscribed instead of recomputing"
    for h in handles:
        assert_outputs_bitwise_equal(h.result, ref)
    assert sum(h.result.coalesced_waits for h in handles) == waits


def test_waiter_computes_only_the_uncovered_remainder(tmp_path):
    """A wider concurrent run coalesces on the overlap and computes only the
    window the winner's claim never covered."""
    rows = 1200
    with PipelineService(
        str(tmp_path / "svc"), workers=2, rows_per_fragment=128
    ) as svc:
        write_events(svc.catalog, 0, rows)
        narrow = svc.submit("alice", slow_project(hi=599))
        time.sleep(0.05)  # let the narrow run claim first
        wide = svc.submit("bob", slow_project(hi=rows - 1))
        svc.drain(60)
        assert narrow.state == "DONE", narrow.error
        assert wide.state == "DONE", wide.error
        # bob recomputed at most the rows outside alice's window
        assert wide.result.rows_to_user_fns <= 2 * (rows - 600)

    ref = cold_reference(
        tmp_path, "coal-wide-ref", slow_project(hi=rows - 1), rows=rows
    )
    assert_outputs_bitwise_equal(wide.result, ref)


def test_failed_owner_releases_and_waiter_recovers(tmp_path):
    """If the claiming run dies mid-residual, its claim is released in a
    finally — the subscriber wakes, replans, claims, and computes.  The two
    runs share one project (identical signature); a module-global token
    (read by name, so it does not enter the code fingerprint) makes exactly
    the FIRST executing run raise."""
    rows = 600
    _BOOM[:] = [1]
    p = Project("boom")

    # verify=False: the _BOOM mutation is deliberate fault injection — the
    # static verifier correctly flags it as hidden state (RPR003)
    @model(project=p, incremental="rowwise", verify=False)
    @runtime("numpy")
    def flaky(
        data=Model("ns.events", columns=["v1", "flag"],
                   filter=f"eventTime BETWEEN 0 AND {rows - 1}")
    ):
        time.sleep(0.2)
        if _BOOM:
            _BOOM.pop()
            raise RuntimeError("boom")
        return data.filter(data.column("flag") > 0)

    with PipelineService(
        str(tmp_path / "svc"), workers=2, rows_per_fragment=128
    ) as svc:
        write_events(svc.catalog, 0, rows)
        handles = [svc.submit("alice", p), svc.submit("bob", p)]
        svc.drain(60)
        states = sorted(h.state for h in handles)
        assert states == ["DONE", "FAILED"], [
            (h.state, h.error) for h in handles
        ]
        winner = next(h for h in handles if h.state == "DONE")
        loser = next(h for h in handles if h.state == "FAILED")
        assert isinstance(loser.error, RuntimeError)

    ws_ref = cold_reference(tmp_path, "boom-ref", p, rows=rows)
    assert_outputs_bitwise_equal(winner.result, ws_ref)
