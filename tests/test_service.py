"""repro.service (ISSUE 4 tentpole): the multi-tenant pipeline service over
one shared, concurrency-safe differential cache.

Covers the SharedStore disciplines (global LRU across tenants, per-tenant
quotas, signature-liveness eviction, reader pins), tenant sessions (snapshot
pinning, commit-retry), the scheduler (states, admission bound, fairness),
cross-tenant cache reuse with bitwise-equal outputs, racing catalog commits
(exactly one CommitConflict; retries converge), the incremental
materializer (ROADMAP (d)), and a threaded stress test (concurrent runs +
appends + evictions on one SharedStore, no torn reads).
"""

import threading
import time

import numpy as np
import pytest

from repro.core.columnar import Table
from repro.core.intervals import IntervalSet
from repro.lake.catalog import Catalog, CommitConflict
from repro.lake.s3sim import ObjectStore
from repro.pipeline import Model, Project, Workspace, model, runtime
from repro.service import (
    DONE,
    FAILED,
    PipelineService,
    QueueFull,
    SharedStore,
    TenantSession,
)

SCHEMA = {"eventTime": "<i8", "v1": "<f8", "v2": "<f8", "flag": "<i8"}
TABLE = "ns.events"


def events_table(lo, hi, seed=0):
    n = hi - lo
    rng = np.random.default_rng(seed + lo)
    return Table(
        {
            "eventTime": np.arange(lo, hi, dtype=np.int64),
            "v1": rng.standard_normal(n),
            "v2": rng.standard_normal(n),
            "flag": rng.integers(0, 4, n).astype(np.int64),
        }
    )


def write_events(catalog, lo, hi, seed=0):
    try:
        catalog.table(TABLE)
    except KeyError:
        catalog.create_table("ns", "events", SCHEMA, "eventTime")
    catalog.append(TABLE, events_table(lo, hi, seed))


def pipeline_project(hi, gain=1.0, materialize=False):
    """cleaned (rowwise drop) -> scored (rowwise map): identical code across
    calls, so every tenant constructing it gets the identical signature."""
    p = Project("svc")

    @model(project=p, incremental="rowwise")
    @runtime("numpy")
    def cleaned(
        data=Model(TABLE, columns=["v1", "v2", "flag"],
                   filter=f"eventTime BETWEEN 0 AND {hi}")
    ):
        return data.filter(data.column("flag") > 0)

    @model(project=p, incremental="rowwise", materialize=materialize)
    @runtime("numpy")
    def scored(data=Model("cleaned")):
        out = {n: data.column(n) for n in data.column_names}
        out["score"] = gain * (
            np.asarray(data.column("v1"), np.float64)
            + np.asarray(data.column("v2"), np.float64)
        )
        return out

    return p


def assert_outputs_bitwise_equal(res_a, res_b):
    assert set(res_a.outputs) == set(res_b.outputs)
    for name in res_a.outputs:
        a, b = res_a.outputs[name], res_b.outputs[name]
        assert a.column_names == b.column_names, name
        for col in a.column_names:
            np.testing.assert_array_equal(
                a.column(col), b.column(col), err_msg=f"{name}:{col}"
            )


def cold_reference(tmp_path, name, project, rows=2000):
    ws = Workspace(str(tmp_path / name), rows_per_fragment=256)
    write_events(ws.catalog, 0, rows)
    return ws.run(project)


# ------------------------------------------------------------ SharedStore unit
def _elem(lo, hi):
    return Table(
        {"k": np.arange(lo, hi, dtype=np.int64), "x": np.arange(lo, hi, dtype=np.float64)}
    )


def test_shared_store_global_lru_spans_tenants():
    elem_bytes = _elem(0, 100).nbytes
    store = SharedStore(max_bytes=2 * elem_bytes)
    store.insert_window("a", "t", "k", IntervalSet.of((0, 100)), _elem(0, 100), tenant="t1")
    store.insert_window("b", "t", "k", IntervalSet.of((0, 100)), _elem(0, 100), tenant="t2")
    store.insert_window("c", "t", "k", IntervalSet.of((0, 100)), _elem(0, 100), tenant="t1")
    assert store.nbytes <= 2 * elem_bytes
    assert store.elements("a") == []  # LRU victim regardless of owner
    assert store.elements("b") and store.elements("c")


def test_shared_store_tenant_quota_evicts_own_elements_only():
    elem_bytes = _elem(0, 100).nbytes
    store = SharedStore(tenant_quota_bytes=2 * elem_bytes)
    store.insert_window("x", "t", "k", IntervalSet.of((0, 100)), _elem(0, 100), tenant="t2")
    for sig in ("a", "b", "c"):
        store.insert_window(sig, "t", "k", IntervalSet.of((0, 100)), _elem(0, 100), tenant="t1")
    assert store.tenant_bytes("t1") <= 2 * elem_bytes
    assert store.quota_evictions == 1
    assert store.elements("a") == []  # t1's eldest went
    assert store.elements("x"), "another tenant's bytes must survive t1's quota"


def test_shared_store_liveness_reclaims_stale_signatures():
    store = SharedStore(liveness_runs=3)
    store.insert_window("old", "t", "k", IntervalSet.of((0, 50)), _elem(0, 50))
    cost = lambda w: w.measure()
    for _ in range(5):
        store.begin_run()
        store.plan_window("hot", IntervalSet.of((0, 50)), (), cost)
    assert store.elements("old") == []
    assert store.liveness_evictions == 1
    # the planned-every-run signature group is never reclaimed
    store.insert_window("hot", "t", "k", IntervalSet.of((0, 50)), _elem(0, 50))
    for _ in range(2):
        store.begin_run()
        store.plan_window("hot", IntervalSet.of((0, 50)), (), cost)
    assert store.elements("hot")


def test_shared_store_reader_pin_blocks_every_eviction_path():
    elem_bytes = _elem(0, 100).nbytes
    store = SharedStore(max_bytes=1 * elem_bytes, liveness_runs=1)
    store.insert_window("pinned", "t", "k", IntervalSet.of((0, 100)), _elem(0, 100))
    with store.reading("pinned"):
        # LRU: inserting over budget must not evict the pinned group
        store.insert_window("other", "t", "k", IntervalSet.of((0, 100)), _elem(0, 100))
        assert store.elements("pinned")
        # liveness: many runs without a plan touching "pinned"
        for _ in range(5):
            store.begin_run()
        assert store.elements("pinned")
    # pin released: the next insert's LRU pass may now reclaim it
    store.insert_window("third", "t", "k", IntervalSet.of((0, 100)), _elem(0, 100))
    assert store.nbytes <= elem_bytes


def test_scan_cache_policies_are_live_in_the_service(tmp_path):
    """The shared SCAN cache gets the same service policies as the model
    store: its liveness clock ticks per run and its elements carry tenant
    attribution (cross-tenant reuse counted)."""
    with PipelineService(
        str(tmp_path / "svc"), workers=1, rows_per_fragment=256, liveness_runs=2
    ) as svc:
        write_events(svc.catalog, 0, 500)
        svc.session("alice").run(pipeline_project(hi=499))
        assert svc.scan_cache.run_seq > 0
        assert svc.scan_cache.elements(TABLE)
        elems = svc.scan_cache.elements(TABLE)
        assert all(e.owner == "alice" for e in elems)
        # a plain (non-incremental) project always scans, so bob's nested
        # read hits alice's scan element directly
        scan_only = Project("scanonly")

        @model(project=scan_only)
        def reader(
            data=Model(TABLE, columns=["v1"], filter="eventTime BETWEEN 0 AND 299")
        ):
            return {"v1": data.column("v1")}

        rb = svc.session("bob").run(scan_only)
        assert rb.bytes_from_store == 0 and rb.bytes_from_cache > 0
        assert svc.scan_cache.cross_tenant_hits > 0
        # a table no run scans for liveness_runs runs is reclaimed
        other = Project("other")

        @model(project=other)
        def nothing(data=Model("ns.unused", columns=["v1"])):
            return data

        svc.catalog.create_table("ns", "unused", SCHEMA, "eventTime")
        svc.session("alice").refresh_pins(["ns.unused"])
        for _ in range(4):
            svc.session("alice").run(other)
        assert svc.scan_cache.elements(TABLE) == []
        assert svc.scan_cache.liveness_evictions > 0


def test_shared_store_counts_cross_tenant_reuse():
    store = SharedStore()
    store.insert_window("s", "t", "k", IntervalSet.of((0, 100)), _elem(0, 100), tenant="alice")
    cost = lambda w: w.measure()
    plan = store.plan_window("s", IntervalSet.of((0, 80)), (), cost, tenant="bob")
    assert plan.fully_cached
    assert store.cross_tenant_hits == 1
    assert store.cross_tenant_rows == 80
    # a tenant re-reading its own bytes is not cross-tenant reuse
    store.plan_window("s", IntervalSet.of((0, 80)), (), cost, tenant="alice")
    assert store.cross_tenant_hits == 1


# --------------------------------------------------- cross-tenant cache reuse
def test_second_tenant_pays_only_residual(tmp_path):
    with PipelineService(str(tmp_path / "svc"), workers=2, rows_per_fragment=256) as svc:
        write_events(svc.catalog, 0, 2000)
        ra = svc.session("alice").run(pipeline_project(hi=1599))
        rb = svc.session("bob").run(pipeline_project(hi=1999))
        # bob's plan subtracts alice's windows: only (1599, 1999] recomputes
        assert rb.node_stats["cleaned"]["fresh_rows"] == 400
        assert rb.bytes_from_model_cache > 0
        assert svc.model_store.cross_tenant_hits > 0
        assert 0 < rb.bytes_from_store < ra.bytes_from_store / 2
        cold = cold_reference(tmp_path, "bob-cold", pipeline_project(hi=1999))
        assert_outputs_bitwise_equal(rb, cold)


def test_nested_window_tenant_is_fully_served(tmp_path):
    with PipelineService(str(tmp_path / "svc"), workers=2, rows_per_fragment=256) as svc:
        write_events(svc.catalog, 0, 2000)
        svc.session("alice").run(pipeline_project(hi=1999))
        rb = svc.session("bob").run(pipeline_project(hi=999))
        assert rb.rows_to_user_fns == 0
        assert rb.bytes_from_store == 0
        assert_outputs_bitwise_equal(
            rb, cold_reference(tmp_path, "nested-cold", pipeline_project(hi=999))
        )


# ------------------------------------------------------------ tenant sessions
def test_session_pins_freeze_the_lake_view(tmp_path):
    with PipelineService(str(tmp_path / "svc"), workers=1, rows_per_fragment=256) as svc:
        write_events(svc.catalog, 0, 1000)
        alice = svc.session("alice")  # pins at 1000 rows
        svc.catalog.append(TABLE, events_table(1000, 1500, seed=5))
        r1 = alice.run(pipeline_project(hi=1999))
        # bob's session pins AFTER the append: sees 1500 rows
        bob = svc.session("bob")
        r2 = bob.run(pipeline_project(hi=1999))
        assert r1.outputs["scored"].num_rows < r2.outputs["scored"].num_rows
        # refreshing alice's pins catches her up, reusing bob's bytes
        alice.refresh_pins()
        r3 = alice.run(pipeline_project(hi=1999))
        assert r3.outputs["scored"].num_rows == r2.outputs["scored"].num_rows
        assert r3.rows_to_user_fns == 0  # bob already paid for the delta


def test_explicit_model_snapshot_beats_session_pin(tmp_path):
    with PipelineService(str(tmp_path / "svc"), workers=1, rows_per_fragment=256) as svc:
        write_events(svc.catalog, 0, 500)
        old = svc.catalog.current_snapshot(TABLE).snapshot_id
        svc.catalog.append(TABLE, events_table(500, 800, seed=2))
        session = svc.session("alice")  # pins at 800 rows
        p = Project("tt")

        @model(project=p, incremental="rowwise")
        def pinned(
            data=Model(TABLE, columns=["v1"], filter="eventTime BETWEEN 0 AND 999",
                       snapshot_id=old)
        ):
            return {n: data.column(n) for n in data.column_names}

        res = session.run(p)
        assert res.outputs["pinned"].num_rows == 500  # user pin wins


# ----------------------------------------------------------------- scheduler
def test_scheduler_states_and_failure_isolation(tmp_path):
    with PipelineService(str(tmp_path / "svc"), workers=2, rows_per_fragment=256) as svc:
        write_events(svc.catalog, 0, 500)
        ok = svc.submit("alice", pipeline_project(hi=499))

        p_bad = Project("bad")

        @model(project=p_bad)
        def broken(data=Model(TABLE, columns=["v1"], filter="eventTime < 100")):
            raise RuntimeError("user code exploded")

        bad = svc.submit("bob", p_bad)
        ok.wait(30)
        bad.wait(30)
        assert ok.state == DONE and ok.result is not None
        assert bad.state == FAILED and isinstance(bad.error, RuntimeError)
        # the failed run neither killed a worker nor poisoned the service
        again = svc.submit("bob", pipeline_project(hi=499)).wait(30)
        assert again.state == DONE


def test_scheduler_admission_bound(tmp_path):
    with PipelineService(
        str(tmp_path / "svc"), workers=1, rows_per_fragment=256, max_queued=2
    ) as svc:
        write_events(svc.catalog, 0, 500)

        release = threading.Event()
        p_slow = Project("slow")

        @model(project=p_slow)
        def blocker(data=Model(TABLE, columns=["v1"], filter="eventTime < 10")):
            release.wait(30)
            return data

        h = svc.submit("alice", p_slow)
        while h.state != "RUNNING":
            time.sleep(0.005)
        svc.submit("bob", pipeline_project(hi=99))
        svc.submit("carol", pipeline_project(hi=99))
        with pytest.raises(QueueFull):
            svc.submit("dave", pipeline_project(hi=99))
        release.set()


def test_scheduler_fairness_many_vs_one(tmp_path):
    """A tenant submitting a burst must not starve another tenant's single
    run: with round-robin pick, bob's run is dispatched no later than
    alice's second queued run."""
    with PipelineService(str(tmp_path / "svc"), workers=1, rows_per_fragment=256) as svc:
        write_events(svc.catalog, 0, 500)
        order = []
        lock = threading.Lock()

        def tracked(tag, hi):
            p = Project(f"t{tag}{hi}")

            @model(project=p)
            def track(data=Model(TABLE, columns=["v1"], filter=f"eventTime < {hi}")):
                with lock:
                    order.append(tag)
                return data

            return p

        gate = threading.Event()
        p_gate = Project("gate")

        @model(project=p_gate)
        def hold(data=Model(TABLE, columns=["v1"], filter="eventTime < 5")):
            gate.wait(30)
            return data

        svc.submit("alice", p_gate)
        for i in range(4):
            svc.submit("alice", tracked("a", 20 + i))
        svc.submit("bob", tracked("b", 50))
        gate.set()
        svc.drain(60)
        assert order.index("b") <= 1, order


# ------------------------------------------- racing commits (satellite task)
def test_two_racing_writers_surface_exactly_one_conflict(tmp_path):
    store = ObjectStore(str(tmp_path / "lake"))
    catalog = Catalog(store, rows_per_fragment=256)
    write_events(catalog, 0, 100)
    parent = catalog.current_snapshot(TABLE).snapshot_id

    barrier = threading.Barrier(2)
    outcomes = []
    olock = threading.Lock()

    def writer(lo):
        barrier.wait()
        try:
            catalog.append(TABLE, events_table(lo, lo + 50), expected_parent=parent)
            result = "ok"
        except CommitConflict:
            result = "conflict"
        with olock:
            outcomes.append(result)

    threads = [threading.Thread(target=writer, args=(lo,)) for lo in (100, 200)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert sorted(outcomes) == ["conflict", "ok"]


def test_session_retry_converges_with_both_snapshots_in_chain(tmp_path):
    store = ObjectStore(str(tmp_path / "lake"))
    catalog = Catalog(store, rows_per_fragment=256)
    write_events(catalog, 0, 100)
    base = catalog.current_snapshot(TABLE)

    def make_session(name):
        ws = Workspace(store.root, store=store, catalog=catalog, tenant=name)
        return TenantSession(name, ws)

    s1, s2 = make_session("w1"), make_session("w2")
    barrier = threading.Barrier(2)
    errors = []

    def writer(session, lo):
        barrier.wait()
        try:
            session.append(TABLE, events_table(lo, lo + 50))
        except BaseException as e:  # pragma: no cover - diagnostic
            errors.append(e)

    threads = [
        threading.Thread(target=writer, args=(s, lo))
        for s, lo in ((s1, 100), (s2, 200))
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    history = catalog.history(TABLE)
    assert history[0].snapshot_id != base.snapshot_id or history[-1].sequence == base.sequence + 2
    assert history[-1].sequence == base.sequence + 2  # both commits landed
    rows = sum(f.row_count for f in history[-1].fragments)
    assert rows == 200  # 100 base + both writers' 50


# ----------------------------------- incremental materialization (ROADMAP d)
def read_published(catalog, store, name="scored"):
    """The models.<name> table's full current content, sorted by key."""
    from repro.core.planner import ScanExecutor
    from repro.core.baselines import NoCache

    ex = ScanExecutor(store, catalog, cache=NoCache())
    meta = catalog.table(f"models.{name}")
    cols = sorted(meta.schema)
    return ex.scan(f"models.{name}", cols, sorted_output=True).combine()


def assert_published_mirrors(ws, res, name="scored"):
    pub = read_published(ws.catalog, ws.store, name)
    out = res.outputs[name]
    assert pub.num_rows == out.num_rows
    for col in out.column_names:
        np.testing.assert_array_equal(
            np.asarray(pub.column(col)), np.asarray(out.column(col)), err_msg=col
        )


def test_materialize_rerun_does_not_duplicate(tmp_path):
    ws = Workspace(str(tmp_path / "lake"), rows_per_fragment=256)
    write_events(ws.catalog, 0, 1000)
    r1 = ws.run(pipeline_project(hi=799, materialize=True))
    seq1 = ws.catalog.current_snapshot("models.scored").sequence
    r2 = ws.run(pipeline_project(hi=799, materialize=True))
    assert_published_mirrors(ws, r2)
    # nothing recomputed -> nothing committed
    assert ws.catalog.current_snapshot("models.scored").sequence == seq1


def test_materialize_widen_appends_residual_only(tmp_path):
    ws = Workspace(str(tmp_path / "lake"), rows_per_fragment=256)
    write_events(ws.catalog, 0, 1000)
    ws.run(pipeline_project(hi=499, materialize=True))
    published_before = read_published(ws.catalog, ws.store).num_rows
    res = ws.run(pipeline_project(hi=999, materialize=True))
    snap = ws.catalog.current_snapshot("models.scored")
    assert snap.operation == "append"
    appended = sum(f.row_count for f in snap.fragments) - published_before
    assert appended == res.outputs["scored"].num_rows - published_before
    assert_published_mirrors(ws, res)


def test_materialize_upstream_overwrite_rewrites_window(tmp_path):
    ws = Workspace(str(tmp_path / "lake"), rows_per_fragment=256)
    write_events(ws.catalog, 0, 1000)
    ws.run(pipeline_project(hi=999, materialize=True))
    seq_before = ws.catalog.current_snapshot("models.scored").sequence
    ws.catalog.overwrite_range(TABLE, 300, 400, events_table(300, 400, seed=42))
    res = ws.run(pipeline_project(hi=999, materialize=True))
    assert_published_mirrors(ws, res)
    # the whole diff lands atomically: readers never see a torn mid-publish
    # state between separate delete/overwrite/append commits
    assert ws.catalog.current_snapshot("models.scored").sequence == seq_before + 1


def test_materialize_narrow_deletes_stale_rows(tmp_path):
    ws = Workspace(str(tmp_path / "lake"), rows_per_fragment=256)
    write_events(ws.catalog, 0, 1000)
    ws.run(pipeline_project(hi=999, materialize=True))
    res = ws.run(pipeline_project(hi=399, materialize=True))
    assert_published_mirrors(ws, res)
    # widening back must restore the full mirror from cache-served rows
    res2 = ws.run(pipeline_project(hi=999, materialize=True))
    assert_published_mirrors(ws, res2)


def test_materialize_code_edit_republishes_in_full(tmp_path):
    ws = Workspace(str(tmp_path / "lake"), rows_per_fragment=256)
    write_events(ws.catalog, 0, 1000)
    ws.run(pipeline_project(hi=999, materialize=True))
    res = ws.run(pipeline_project(hi=999, gain=2.0, materialize=True))
    assert ws.catalog.current_snapshot("models.scored").operation == "overwrite"
    assert_published_mirrors(ws, res)


def test_materialize_republishes_windows_freshened_by_other_runs(tmp_path):
    """Republication is keyed on the PUBLISHED leaf snapshot, not on what
    this run recomputed: when another tenant's non-materializing run already
    freshened the overwritten window into the shared cache, the materializing
    run serves it as a cache hit — and must still republish it."""
    with PipelineService(str(tmp_path / "svc"), workers=1, rows_per_fragment=256) as svc:
        write_events(svc.catalog, 0, 1000)
        publisher = svc.session("publisher")
        res = publisher.run(pipeline_project(hi=999, materialize=True))
        assert_published_mirrors(publisher.workspace, res)
        # upstream overwrite, then a DIFFERENT tenant (no materialize) pays
        # for the recompute, leaving the shared cache fresh
        svc.catalog.overwrite_range(TABLE, 300, 400, events_table(300, 400, seed=9))
        other = svc.session("other")
        other.run(pipeline_project(hi=999, materialize=False))
        # the publisher's run is now a pure cache hit...
        publisher.refresh_pins([TABLE])
        res2 = publisher.run(pipeline_project(hi=999, materialize=True))
        assert res2.rows_to_user_fns == 0
        # ...and the published table still picks up the overwritten window
        assert_published_mirrors(publisher.workspace, res2)


def test_code_fingerprint_sees_kwonly_defaults(tmp_path):
    """A keyword-only default lives in __kwdefaults__; editing it must
    invalidate like any other constant edit."""
    from repro.pipeline.dsl import code_fingerprint

    def make(gain):
        def fn(data=Model(TABLE, columns=["v1"]), *, g=gain):
            return {"s": g * data.column("v1")}

        return fn

    assert code_fingerprint(make(2.0)) != code_fingerprint(make(3.0))
    assert code_fingerprint(make(2.0)) == code_fingerprint(make(2.0))


def test_materialize_upstream_append_into_covered_range(tmp_path):
    ws = Workspace(str(tmp_path / "lake"), rows_per_fragment=256)
    write_events(ws.catalog, 0, 1000)
    ws.run(pipeline_project(hi=1999, materialize=True))
    write_events(ws.catalog, 1000, 1200, seed=4)
    res = ws.run(pipeline_project(hi=1999, materialize=True))
    assert_published_mirrors(ws, res)


def test_concurrent_materialize_of_new_model_converges(tmp_path):
    """Two tenants materializing the same brand-new model race on
    create_table AND on content commits; both runs must converge (the create
    loser adopts the winner's table, commit losers retry via the session)."""
    with PipelineService(str(tmp_path / "svc"), workers=2, rows_per_fragment=256) as svc:
        write_events(svc.catalog, 0, 1000)
        h1 = svc.submit("alice", pipeline_project(hi=999, materialize=True))
        h2 = svc.submit("bob", pipeline_project(hi=999, materialize=True))
        h1.wait(60)
        h2.wait(60)
        assert h1.state == DONE, h1.error
        assert h2.state == DONE, h2.error
        assert_published_mirrors(svc.session("alice").workspace, h1.result)


def test_session_reads_its_own_publishes(tmp_path):
    """A run that materializes a model advances the session's pin for the
    published table — the tenant's next scan sees the fresh snapshot even
    though the table was pinned before the publish."""
    with PipelineService(str(tmp_path / "svc"), workers=1, rows_per_fragment=256) as svc:
        write_events(svc.catalog, 0, 1000)
        svc.session("bootstrap").run(pipeline_project(hi=299, materialize=True))
        alice = svc.session("alice")  # pins models.scored at the 300-row publish
        res = alice.run(pipeline_project(hi=999, materialize=True))

        consumer = Project("consumer")

        @model(project=consumer)
        def reader(d=Model("models.scored", columns=["score"])):
            return {"score": d.column("score")}

        seen = alice.run(consumer).outputs["reader"].num_rows
        assert seen == res.outputs["scored"].num_rows


# ------------------------------------------------------- threaded stress test
def test_threaded_stress_no_torn_reads(tmp_path):
    """Concurrent pipeline runs + catalog appends + forced evictions on ONE
    SharedStore: every run's outputs must be bitwise-equal to a cold run of
    the same project against the session's pinned snapshot."""
    rows = 1200
    with PipelineService(
        str(tmp_path / "svc"),
        workers=4,
        rows_per_fragment=128,
        model_cache_bytes=50_000,  # well under the working set: eviction churn
        liveness_runs=4,
    ) as svc:
        write_events(svc.catalog, 0, rows)
        # pin reader sessions BEFORE the writer starts: their reference
        # output is deterministic whatever the writer commits
        readers = [svc.session(t) for t in ("alice", "bob")]

        stop = threading.Event()

        def appender():
            session = svc.session("writer")
            lo = rows
            while not stop.is_set():
                session.append(TABLE, events_table(lo, lo + 64, seed=7))
                lo += 64
                time.sleep(0.002)

        wt = threading.Thread(target=appender)
        wt.start()
        try:
            his = [399, 799, 1199, 599, 999, 1199, 399, 1099]
            handles = [
                svc.submit(readers[i % 2].tenant_id, pipeline_project(hi=hi))
                for i, hi in enumerate(his)
            ]
            svc.drain(120)
        finally:
            stop.set()
            wt.join()

        refs = {}
        for hi, h in zip(his, handles):
            assert h.state == DONE, h.error
            if hi not in refs:
                refs[hi] = cold_reference(
                    tmp_path, f"stress-cold-{hi}-{len(refs)}",
                    pipeline_project(hi=hi), rows=rows,
                )
            assert_outputs_bitwise_equal(h.result, refs[hi])
        assert svc.model_store.evictions > 0, "stress must actually evict"
        rep = svc.report()
        assert rep.model_store["cross_tenant_hits"] > 0


# -------------------------------------------------- acceptance: the >=3x gate
def test_service_bench_meets_3x_acceptance():
    """The BENCH_4 scenario (same code CI smokes): every warm tenant —
    including those with windows widened past the shared coverage — moves
    >=3x fewer bytes from the store than its own cold run, with bitwise-equal
    outputs (asserted inside bench4.run)."""
    from benchmarks import bench4_service as b4

    result = b4.run(rows=4000, tenants=3)
    assert result["min_bytes_ratio"] >= 3.0, result
    assert result["min_rows_ratio"] >= 3.0, result
    assert result["model_store"]["cross_tenant_hits"] > 0
