"""Unit + property tests for the exact interval algebra the cache rests on,
including the joint-window algebra of multi-input incrementality and the
multi-table validity rule (``snapshots_usable_window``) against a pointwise
oracle."""

from types import SimpleNamespace

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cache import FragmentPin, snapshots_usable_window
from repro.core.intervals import EMPTY, EVERYTHING, Interval, IntervalSet


def ivs(*pairs):
    return IntervalSet.of(*pairs)


# ---------------------------------------------------------------- unit tests
def test_normalization_merges_overlap_and_adjacency():
    s = ivs((0, 5), (5, 10), (12, 15), (14, 20), (30, 30))
    assert s.to_pairs() == ((0, 10), (12, 20))


def test_difference_splits():
    s = ivs((0, 100)).difference(ivs((10, 20), (30, 40)))
    assert s.to_pairs() == ((0, 10), (20, 30), (40, 100))


def test_difference_paper_workload():
    # §III-A: user A cached Jan, user B wants Jan..Feb -> residual is Feb.
    jan = ivs((20230101, 20230201))
    jan_feb = ivs((20230101, 20230301))
    assert jan_feb.difference(jan).to_pairs() == ((20230201, 20230301),)
    # user A's debug day is fully covered by the cached Jan window
    day = ivs((20230101, 20230102))
    assert jan.covers(day)
    assert day.difference(jan).empty


def test_intersect():
    assert ivs((0, 10), (20, 30)).intersect(ivs((5, 25))).to_pairs() == ((5, 10), (20, 25))


def test_measure_and_span():
    s = ivs((0, 10), (20, 25))
    assert s.measure() == 15
    assert s.span().lo == 0 and s.span().hi == 25


def test_contains_point():
    s = ivs((0, 10), (20, 25))
    assert s.contains_point(0) and s.contains_point(9)
    assert not s.contains_point(10) and not s.contains_point(19)
    assert s.contains_point(24) and not s.contains_point(25)


def test_everything_and_empty():
    assert EVERYTHING.covers(ivs((-(10**9), 10**9)))
    assert EMPTY.empty
    assert (EVERYTHING - EVERYTHING).empty


# ------------------------------------------------------------ property tests
pair = st.tuples(st.integers(-1000, 1000), st.integers(-1000, 1000))
iset = st.lists(pair, max_size=6).map(
    lambda ps: IntervalSet.of(*[(min(a, b), max(a, b)) for a, b in ps])
)


def points(s: IntervalSet):
    return {x for iv in s for x in range(iv.lo, iv.hi)}


@settings(max_examples=200, deadline=None)
@given(iset, iset)
def test_union_matches_pointwise(a, b):
    assert points(a.union(b)) == points(a) | points(b)


@settings(max_examples=200, deadline=None)
@given(iset, iset)
def test_intersect_matches_pointwise(a, b):
    assert points(a.intersect(b)) == points(a) & points(b)


@settings(max_examples=200, deadline=None)
@given(iset, iset)
def test_difference_matches_pointwise(a, b):
    assert points(a.difference(b)) == points(a) - points(b)


@settings(max_examples=200, deadline=None)
@given(iset, iset)
def test_residual_partition(a, b):
    """The cache's core identity: covered ⊔ residual == scan, disjoint."""
    covered = a.intersect(b)
    residual = a.difference(b)
    assert covered.intersect(residual).empty
    assert covered.union(residual) == a


@settings(max_examples=200, deadline=None)
@given(iset, iset, iset)
def test_demorgan_via_difference(a, b, c):
    assert a.difference(b.union(c)) == a.difference(b).difference(c)


@settings(max_examples=200, deadline=None)
@given(iset)
def test_normal_form_canonical(s):
    # re-normalizing is a no-op and equality is semantic
    assert IntervalSet(s.intervals) == s
    assert IntervalSet.of(*reversed(s.to_pairs())) == s


# ----------------------------------------- randomized cache-algebra invariants
@settings(max_examples=200, deadline=None)
@given(iset, iset)
def test_partition_reassembles_exactly(a, b):
    """(A - B) | (A & B) == A — the cache's residual+hit reassembly: what is
    fetched plus what is served must be exactly the requested scan."""
    assert a.difference(b).union(a.intersect(b)) == a


@settings(max_examples=200, deadline=None)
@given(iset, iset)
def test_union_length_subadditive(a, b):
    """|A ∪ B| ≤ |A| + |B|, with equality iff disjoint — byte accounting in
    the planner relies on measure() never double-counting merged windows."""
    u = a.union(b)
    assert u.measure() <= a.measure() + b.measure()
    if a.intersect(b).empty:
        assert u.measure() == a.measure() + b.measure()
    assert u.measure() >= max(a.measure(), b.measure())


@settings(max_examples=200, deadline=None)
@given(iset, iset)
def test_difference_coverage_roundtrip(a, b):
    """Difference/coverage round-trips: removing what B covers and adding it
    back restores A; coverage is equivalent to an empty residual."""
    residual = a.difference(b)
    covered = a.intersect(b)
    # round-trip: A \ B ⊎ (A ∩ B) partitions A
    assert residual.union(covered) == a
    assert residual.intersect(covered).empty
    # covers() <=> zero residual, and double difference is idempotent
    assert b.covers(a) == a.difference(b).empty
    assert residual.difference(b) == residual
    # self-algebra sanity
    assert a.difference(a).empty
    assert a.covers(covered)


# --------------------------------- joint windows (multi-input incrementality)
def _joint(windows):
    joint = windows[0]
    for w in windows[1:]:
        joint = joint.intersect(w)
    return joint


@settings(max_examples=200, deadline=None)
@given(st.lists(iset, min_size=2, max_size=4))
def test_joint_window_is_intersection_pointwise(windows):
    """A multi-input node's window is the INTERSECTION of its input windows:
    exactly the keys every input can supply rows for."""
    pts = points(windows[0])
    for w in windows[1:]:
        pts &= points(w)
    assert points(_joint(windows)) == pts


@settings(max_examples=200, deadline=None)
@given(st.lists(iset, min_size=2, max_size=4), iset)
def test_joint_residual_partitions_and_aligns_per_input(windows, usable):
    """The multi-input executor identity: hit ⊔ residual partitions the
    joint window, and the residual lies inside EVERY input window — so each
    input's residual slice is the same key range (zip alignment over the
    shared sort key is well-defined)."""
    joint = _joint(windows)
    hit = joint.intersect(usable)
    residual = joint.difference(usable)
    assert hit.intersect(residual).empty
    assert hit.union(residual) == joint
    for w in windows:
        assert w.covers(residual)
        assert residual.intersect(w) == residual


# --------------------------- multi-table validity (snapshots_usable_window)
# a fragment is (key_lo, width) — key range [key_lo, key_lo+width] inclusive
_frag = st.tuples(st.integers(-60, 60), st.integers(0, 12))
# per table: pinned fragments each with a still-live flag, plus new
# (never-pinned) fragments that appeared after the element was built
_table_state = st.tuples(
    st.lists(st.tuples(_frag, st.booleans()), max_size=4),
    st.lists(_frag, max_size=3),
)
_small_iset = st.lists(
    st.tuples(st.integers(-80, 80), st.integers(-80, 80)), max_size=4
).map(lambda ps: IntervalSet.of(*[(min(a, b), max(a, b)) for a, b in ps]))


def _snap(table, pinned, new):
    frags = [
        SimpleNamespace(fragment_id=f"{table}-old-{i}", key_min=lo, key_max=lo + w)
        for i, ((lo, w), live) in enumerate(pinned)
        if live
    ] + [
        SimpleNamespace(fragment_id=f"{table}-new-{j}", key_min=lo, key_max=lo + w)
        for j, (lo, w) in enumerate(new)
    ]
    return SimpleNamespace(
        fragments=frags, fragment_ids=frozenset(f.fragment_id for f in frags)
    )


@settings(max_examples=200, deadline=None)
@given(_small_iset, _table_state, _table_state)
def test_snapshots_usable_window_matches_pointwise_oracle(window, left, right):
    """Multi-table validity: usable = window − ⋃ per-table (stale ∪ unseen),
    checked against a brute-force pointwise oracle.  The element's own-table
    pins stay UNLABELED (table=None) — the back-compat path single-leaf
    elements and old spill manifests rely on."""
    pins = tuple(
        FragmentPin(f"L-old-{i}", lo, lo + w, None)  # None -> elem.table ("L")
        for i, ((lo, w), _) in enumerate(left[0])
    ) + tuple(
        FragmentPin(f"R-old-{i}", lo, lo + w, "R") for i, ((lo, w), _) in enumerate(right[0])
    )
    elem = SimpleNamespace(window=window, table="L", pins=pins)
    snaps = {"L": _snap("L", *left), "R": _snap("R", *right)}

    got = snapshots_usable_window(elem, snaps)

    expected = points(window)
    for table in snaps:
        live = snaps[table].fragment_ids
        seen = {p.fragment_id for p in pins if (p.table or "L") == table}
        for p in pins:
            if (p.table or "L") == table and p.fragment_id not in live:
                expected -= set(range(p.key_min, p.key_max + 1))  # stale
        for f in snaps[table].fragments:
            if f.fragment_id not in seen:
                expected -= set(range(f.key_min, f.key_max + 1))  # unseen
    assert points(got) == expected
