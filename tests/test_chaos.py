"""Chaos edit-matrix suite (ISSUE 10): the differential cache under an
object store that fails.

Every test drives real pipelines through seeded :class:`FaultPlan`s —
transient request failures, latency spikes, torn (truncated) uploads,
at-rest bit rot, and process crashes mid-publish — and holds the line on
ONE property: outputs stay **bitwise-equal to a fault-free run**, and zero
corrupt bytes are ever served (corruption is detected, quarantined and
recomputed, never returned).  Plans are seeded and op-count-keyed, so every
chaos schedule here is exactly reproducible.
"""

import os
import threading

import numpy as np
import pytest

from edit_matrix import assert_outputs_bitwise_equal, standard_matrix, sweep
from repro.core.cache import DifferentialCache, DifferentialStore
from repro.core.spill import SpillCorruption, SpillTier
from repro.dist.fault import SimClock
from repro.lake import (
    FaultPlan,
    FaultyObjectStore,
    InjectedCrash,
    RetryPolicy,
    TransientStoreError,
)
from repro.lake.catalog import Catalog
from repro.lake.s3sim import ObjectStore
from repro.obs import Metrics
from repro.pipeline import Workspace
from repro.service import PipelineService

from test_incremental import SCHEMA, events_table, feature_project
from test_service import (
    TABLE,
    cold_reference,
    pipeline_project,
    write_events,
)


def _retry(clock, attempts=6):
    """Store-level retry with an instant simulated clock."""
    return RetryPolicy(max_attempts=attempts, base_delay_s=0.001, clock=clock)


def _seed_catalog(catalog):
    catalog.create_table("ns", "raw", SCHEMA, "eventTime")
    catalog.append("ns.raw", events_table(0, 1000))


# ------------------------------------------------------------ fault plan unit
def test_fault_plan_is_deterministic_and_seed_sensitive():
    mk = lambda seed: FaultPlan(seed=seed, transient_rate=0.3, latency_spike_rate=0.2)
    a, b, c = mk(11), mk(11), mk(12)
    seq = lambda p: [
        (d.transient, d.latency_s > 0)
        for d in (p.decide("get", "k") for _ in range(64))
    ]
    sa, sb, sc = seq(a), seq(b), seq(c)
    assert sa == sb  # same seed, same workload => identical schedule
    assert sa != sc  # a different seed is a different schedule
    assert any(t for t, _ in sa) and any(s for _, s in sa)


def test_retry_absorbs_transients_and_counts_them(tmp_path):
    clock = SimClock()
    plan = FaultPlan(seed=5, transient_rate=0.4)
    store = FaultyObjectStore(str(tmp_path), plan=plan, retry=_retry(clock))
    store.metrics = m = Metrics()
    for i in range(30):
        store.put(f"k/{i}", b"x" * 64)
    for i in range(30):
        assert store.get_range(f"k/{i}", 0, 64) == b"x" * 64
    assert plan.transients_injected > 0
    assert m.total("store_retries") == plan.transients_injected
    assert m.total("store_giveups") == 0
    assert clock.time() > 0  # backoff elapsed on the simulated clock only


def test_giveup_after_retry_budget(tmp_path):
    plan = FaultPlan(seed=0, transient_rate=1.0)  # every attempt fails
    store = FaultyObjectStore(
        str(tmp_path), plan=plan, retry=_retry(SimClock(), attempts=3)
    )
    store.metrics = m = Metrics()
    with pytest.raises(TransientStoreError):
        store.put("k", b"payload")
    assert m.total("store_retries") == 2
    assert m.total("store_giveups") == 1


# ------------------------------------------- the 11-edit matrix under faults
def test_edit_matrix_under_transient_faults(tmp_path):
    """The canonical 11-edit sweep with transients + latency spikes on every
    request: the retry layer must absorb all of it — same answers, same
    zero-recompute guarantees, bitwise-equal to plain cold references."""
    clock = SimClock()
    plan = FaultPlan(seed=42, transient_rate=0.15, latency_spike_rate=0.1)

    def setup(root):
        # the warm workspace lives on the faulted store; every cold
        # reference runs fault-free, so equality proves no fault leaked
        if root.endswith("em-warm"):
            store = FaultyObjectStore(root, plan=plan, retry=_retry(clock))
        else:
            store = ObjectStore(root)
        ws = Workspace(root, store=store)
        _seed_catalog(ws.catalog)
        return ws

    append = lambda c: c.append("ns.raw", events_table(1000, 1100, seed=9))
    overwrite = lambda c: c.overwrite_range(
        "ns.raw", 100, 200, events_table(100, 200, seed=77)
    )
    edits = standard_matrix(
        base=dict(hi=499),
        widen=dict(hi=999),
        narrow=dict(hi=299),
        beyond=dict(hi=4999),
        feature_add=dict(hi=4999, columns=("c1", "c2", "c3")),
        feature_remove=dict(hi=4999),
        code_edit=dict(hi=4999, gain=2.0),
        append=append,
        overwrite=overwrite,
    )
    sweep(tmp_path, setup, feature_project, edits)
    assert plan.transients_injected > 0, "the chaos schedule never fired"
    assert plan.spikes_injected > 0


def test_edit_matrix_with_corrupted_and_torn_spill(tmp_path):
    """Mid-sweep, one spilled model payload rots at rest and one spill
    upload tears: both must be quarantined + recomputed (explainer cause
    ``spill-corrupt``), with every answer still bitwise-equal."""
    root = str(tmp_path / "em-warm")
    store = ObjectStore(root)
    metrics = Metrics()
    model_store = DifferentialStore(
        spill=SpillTier(store, prefix="_spill/model"),
        metrics=metrics,
        metrics_labels={"store": "model"},
    )

    def setup(r):
        if r == root:
            ws = Workspace(r, store=store, model_store=model_store)
        else:
            ws = Workspace(r)
        try:
            _seed_catalog(ws.catalog)
        except FileExistsError:
            pass  # the warm root persists across the two half-sweeps
        return ws

    edits = standard_matrix(
        base=dict(hi=499),
        widen=dict(hi=999),
        narrow=dict(hi=299),
        beyond=dict(hi=4999),
        feature_add=dict(hi=4999, columns=("c1", "c2", "c3")),
        feature_remove=dict(hi=4999),
        code_edit=dict(hi=4999, gain=2.0),
        append=lambda c: c.append("ns.raw", events_table(1000, 1100, seed=9)),
        overwrite=lambda c: c.overwrite_range(
            "ns.raw", 100, 200, events_table(100, 200, seed=77)
        ),
    )
    head, tail = edits[:5], edits[5:]
    sweep(tmp_path, setup, feature_project, head)

    # park every resident element in the spill tier, then damage two
    # payloads on disk: one bit-flipped (rot), one truncated (torn upload)
    model_store.demote_all()
    payloads = [k for k in store.list("_spill/model") if not k.endswith(".json")]
    assert len(payloads) >= 2, payloads
    flip_path = store.local_path(payloads[0])
    with open(flip_path, "r+b") as f:
        f.seek(os.path.getsize(flip_path) // 2)
        b = f.read(1)
        f.seek(-1, 1)
        f.write(bytes([b[0] ^ 0x01]))
    torn_path = store.local_path(payloads[1])
    with open(torn_path, "r+b") as f:
        f.truncate(os.path.getsize(torn_path) // 2)

    before = metrics.total("corruption_detected")
    sweep(tmp_path, setup, feature_project, tail)
    assert metrics.total("corruption_detected") >= before + 2
    assert metrics.total("spill_quarantined") >= 2
    # the quarantined keys were GC'd, not left to poison a later restart
    left = set(store.list("_spill/model"))
    assert payloads[0] not in left and payloads[1] not in left


def test_crash_restart_mid_sequence(tmp_path):
    """A crash mid-append (fragment puts done, commit never lands) must
    leave the lake exactly as before the edit: restart recovery GCs the
    orphans, the replayed edit commits cleanly, and the continued sweep
    stays bitwise-equal to cold references that never saw a crash."""
    root = str(tmp_path / "em-warm")
    clock = SimClock()
    # the seed commit writes fragments 0..7 (1000 rows / 128); the edit's
    # append is the next data put — crash exactly there
    plan = FaultPlan(seed=2, crash_puts=(8,), key_prefix="data/")
    store = FaultyObjectStore(root, plan=plan, retry=_retry(clock))
    ws = Workspace(root, store=store, rows_per_fragment=128)
    _seed_catalog(ws.catalog)

    ws.run(feature_project(hi=499))
    ws.run(feature_project(hi=999))
    with pytest.raises(InjectedCrash):
        ws.catalog.append("ns.raw", events_table(1000, 1100, seed=9))
    assert plan.crashes_injected == 1
    journal = os.path.join(root, "_catalog", "_journal")
    assert os.listdir(journal), "the wounded publish must leave its intent"

    # restart: fresh objects over the same root; Workspace construction
    # runs journal recovery, so the half-written fragments are GC'd
    ws2 = Workspace(root)
    assert not os.listdir(journal)
    assert ws2.catalog.current_snapshot("ns.raw").sequence == 1  # seed only
    # the edit replays cleanly and the sweep continues, bitwise-equal
    ws2.catalog.append("ns.raw", events_table(1000, 1100, seed=9))
    warm = ws2.run(feature_project(hi=4999))
    cold_ws = Workspace(str(tmp_path / "cold"))
    _seed_catalog(cold_ws.catalog)
    cold_ws.catalog.append("ns.raw", events_table(1000, 1100, seed=9))
    assert_outputs_bitwise_equal(warm, cold_ws.run(feature_project(hi=4999)))


def test_crash_mid_materialize_publish_rolls_back(tmp_path):
    """materialize=True is the multi-write publish the journal exists for:
    kill it mid-fragment-write, restart, and the replayed run must publish
    the same table a never-crashed service would."""
    root = str(tmp_path / "svc")
    write_events(Catalog(ObjectStore(root), rows_per_fragment=256), 0, 2000)
    clock = SimClock()
    plan = FaultPlan(seed=4, crash_puts=(2,), key_prefix="data/models.")
    with PipelineService(
        root, workers=1, rows_per_fragment=256,
        fault_plan=plan, store_retry=_retry(clock),
    ) as svc:
        h = svc.submit("alice", pipeline_project(hi=1599, materialize=True)).wait()
        assert h.state == "FAILED"
        assert isinstance(h.error, InjectedCrash)
        assert plan.crashes_injected == 1

    # restart over the same root: recovery GCs the torn publish's orphans
    with PipelineService(root, workers=1, rows_per_fragment=256) as svc2:
        rec = svc2.journal_recovery
        assert rec["rolled_back"] == 1 and rec["orphans_deleted"] >= 1
        h2 = svc2.submit("alice", pipeline_project(hi=1599, materialize=True)).wait()
        assert h2.state == "DONE"
        published = svc2.catalog.current_snapshot("models.scored")

    ref_root = str(tmp_path / "ref")
    write_events(Catalog(ObjectStore(ref_root), rows_per_fragment=256), 0, 2000)
    with PipelineService(ref_root, workers=1, rows_per_fragment=256) as ref:
        ref.submit("alice", pipeline_project(hi=1599, materialize=True)).wait()
        ref_pub = ref.catalog.current_snapshot("models.scored")
        # identical rows published (fragment ids are uuids; compare content)
        assert sum(f.row_count for f in published.fragments) == sum(
            f.row_count for f in ref_pub.fragments
        )


# --------------------------------------------------- service-level degradation
def test_run_level_retry_recovers_store_giveups(tmp_path):
    """When the store's own retry budget is exhausted (giveups unwind whole
    runs), the service classifies the failure transient and replays the run
    with backoff — completing it bitwise-equal to a fault-free reference."""
    root = str(tmp_path / "svc")
    write_events(Catalog(ObjectStore(root), rows_per_fragment=256), 0, 2000)
    clock = SimClock()
    plan = FaultPlan(seed=8, transient_rate=0.02, key_prefix="data/")
    with PipelineService(
        root, workers=1, rows_per_fragment=256,
        fault_plan=plan,
        store_retry=RetryPolicy(max_attempts=1, clock=clock),  # giveup per fault
        max_run_attempts=10,
        run_retry=RetryPolicy(max_attempts=10, base_delay_s=0.001, clock=clock),
    ) as svc:
        h = svc.submit("alice", pipeline_project(hi=1599)).wait()
        assert h.state == "DONE"
        assert h.attempts > 1, "the schedule must actually force a retry"
        assert svc.metrics.total("run_retries") == h.attempts - 1
        assert svc.metrics.total("runs_quarantined") == 0
        res = h.result
    assert_outputs_bitwise_equal(
        res, cold_reference(tmp_path, "ref", pipeline_project(hi=1599))
    )


def test_poison_run_quarantined_and_user_bugs_not_retried(tmp_path):
    root = str(tmp_path / "svc")
    write_events(Catalog(ObjectStore(root), rows_per_fragment=256), 0, 500)
    clock = SimClock()
    plan = FaultPlan(seed=0, transient_rate=1.0, key_prefix="data/")
    with PipelineService(
        root, workers=1, rows_per_fragment=256,
        fault_plan=plan,
        store_retry=RetryPolicy(max_attempts=2, clock=clock),
        max_run_attempts=3,
        run_retry=RetryPolicy(max_attempts=3, base_delay_s=0.001, clock=clock),
    ) as svc:
        # poison: every data read transient-fails forever => all attempts
        # burn out => quarantined, FAILED, the worker moves on
        h = svc.submit("alice", pipeline_project(hi=399)).wait()
        assert h.state == "FAILED" and h.attempts == 3
        assert svc.metrics.total("runs_quarantined") == 1

    # a deterministic user bug must fail on attempt one — retrying a crash
    # that will always recur is not graceful, it is slow.  Fault-free
    # service: the bug, not the store, is the only failure source.
    from repro.pipeline.dsl import Model, Project, model, runtime

    p = Project("bad")

    @model(project=p, incremental="rowwise")
    @runtime("numpy")
    def boom(data=Model(TABLE, columns=["eventTime"], filter="eventTime <= 10")):
        raise ValueError("user bug")

    with PipelineService(
        root + "2", workers=1, rows_per_fragment=256, max_run_attempts=3,
        run_retry=RetryPolicy(max_attempts=3, base_delay_s=0.001, clock=clock),
    ) as svc2:
        write_events(svc2.catalog, 0, 500)
        h2 = svc2.submit("alice", p).wait()
        assert h2.state == "FAILED" and h2.attempts == 1
        assert isinstance(h2.error, ValueError)
        assert svc2.metrics.total("runs_quarantined") == 0


def test_degraded_ram_only_fallback_when_spill_keeps_failing(tmp_path):
    """A spill tier that cannot write must not take the service down: after
    the failure threshold the store flags itself degraded, stops demoting,
    and keeps serving from RAM — runs still complete correctly."""
    root = str(tmp_path / "svc")
    write_events(Catalog(ObjectStore(root), rows_per_fragment=256), 0, 1000)
    clock = SimClock()
    plan = FaultPlan(seed=0, transient_rate=1.0, key_prefix="_spill/")
    with PipelineService(
        root, workers=1, rows_per_fragment=256,
        fault_plan=plan,
        store_retry=RetryPolicy(max_attempts=2, clock=clock),
        spill=True, spill_mode="write_through",
    ) as svc:
        h = svc.submit("alice", pipeline_project(hi=799)).wait()
        assert h.state == "DONE"
        res = h.result
        # each store counts CONSECUTIVE failures separately; a second run's
        # write-through attempts push the model store past the threshold
        h2 = svc.submit("alice", pipeline_project(hi=999)).wait()
        assert h2.state == "DONE"
        assert svc.model_store.degraded, "spill writes all fail => degraded"
        assert svc.metrics.total("cache_degraded") >= 1
        assert svc.metrics.total("spill_write_failures") >= 3
        assert svc.model_store.stats()["degraded"] is True
    assert_outputs_bitwise_equal(
        res, cold_reference(tmp_path, "ref", pipeline_project(hi=799), rows=1000)
    )


def test_write_through_spill_survives_crash_restart(tmp_path):
    """spill_mode='write_through' parks a spill copy at insert time, so a
    service killed WITHOUT the clean demote-all shutdown still restarts
    warm (satellite of ISSUE 10; PR 5 follow-up f)."""
    root = str(tmp_path / "svc")
    write_events(Catalog(ObjectStore(root), rows_per_fragment=256), 0, 2000)
    svc = PipelineService(
        root, workers=1, rows_per_fragment=256,
        spill=True, spill_mode="write_through",
    )
    r1 = svc.submit("alice", pipeline_project(hi=1599)).wait().result
    assert svc.metrics.total("spill_writethrough_bytes") > 0
    svc.shutdown(wait=False)  # crash: no demote_all flush

    with PipelineService(
        root, workers=1, rows_per_fragment=256, spill=True
    ) as svc2:
        h = svc2.submit("bob", pipeline_project(hi=1599)).wait()
        assert h.state == "DONE"
        r2 = h.result
        assert svc2.metrics.total("spill_restored") > 0
        # warm across the crash: the restarted run recomputes nothing
        assert r2.rows_to_user_fns == 0
        assert r2.bytes_from_spill > 0
    assert_outputs_bitwise_equal(r1, r2)


# -------------------------------------------------- threaded multi-tenant chaos
def test_multi_tenant_threaded_chaos(tmp_path):
    """Four tenants hammer one shared store through worker threads while
    the object store throws transients and latency spikes: every run must
    complete and agree bitwise with a fault-free single-tenant reference."""
    root = str(tmp_path / "svc")
    write_events(Catalog(ObjectStore(root), rows_per_fragment=256), 0, 2000)
    clock = SimClock()
    plan = FaultPlan(seed=13, transient_rate=0.1, latency_spike_rate=0.05)
    tenants = ["alice", "bob", "carol", "dave"]
    his = [799, 999, 1199, 1599]
    with PipelineService(
        root, workers=4, rows_per_fragment=256,
        fault_plan=plan, store_retry=_retry(clock, attempts=8),
        max_run_attempts=4,
        run_retry=RetryPolicy(max_attempts=4, base_delay_s=0.001, clock=clock),
    ) as svc:
        handles = [
            svc.submit(t, pipeline_project(hi=hi))
            for t, hi in zip(tenants, his)
            for _ in range(2)
        ]
        for h in handles:
            h.wait(timeout=120)
            assert h.state == "DONE", repr(h.error)
        results = {h.run_id: h.result for h in handles}
    assert plan.transients_injected > 0
    for (t, hi), h in zip(
        [(t, hi) for t, hi in zip(tenants, his) for _ in range(2)], handles
    ):
        ref = cold_reference(tmp_path, f"ref-{t}-{h.run_id}", pipeline_project(hi=hi))
        assert_outputs_bitwise_equal(results[h.run_id], ref)


# --------------------------------------------------------- bench10 acceptance
def test_bench10_acceptance():
    """The chaos bench's hard invariants at unit-test scale (the wall-time
    overhead gate itself runs in CI at full scale; here we only sanity-check
    the measurement plumbing)."""
    from benchmarks import bench10_chaos as b10

    result = b10.run(rows=2000, reps=1)
    c = result["chaos_loop"]
    assert c["completed"] == c["edits"] and c["bitwise_equal"]
    assert c["corruption_detected"] >= 1 and c["corrupt_bytes_served"] == 0
    assert result["retry_warmth"]["rows_ratio"] >= 3.0
    cr = result["crash_restart"]
    assert cr["recovered_bytes"] > 0 and cr["replay_fresh_rows"] == 0
    assert result["overhead"]["baseline_s"] > 0
    assert "overhead_pct" in result["overhead"]
    table = b10.format_table(result)
    assert "corrupt bytes served: 0" in table
