"""Regression tests for ScanExecutor / ResultCachingExecutor edge cases:
sorted output when the sort key is projected away, and result-cache memo
keys surviving predicate garbage collection."""

import numpy as np
import pytest

from repro.core.baselines import NoCache
from repro.core.cache import DifferentialCache
from repro.core.columnar import Table
from repro.core.intervals import IntervalSet
from repro.core.planner import ResultCachingExecutor, ScanExecutor
from repro.lake.catalog import Catalog
from repro.lake.s3sim import ObjectStore

SCHEMA = {"eventTime": "<i8", "c1": "<f8", "c3": "<i8"}


@pytest.fixture()
def env(tmp_path):
    store = ObjectStore(str(tmp_path / "s3"))
    catalog = Catalog(store, rows_per_fragment=64)
    catalog.create_table("ns", "raw", SCHEMA, "eventTime")
    rng = np.random.default_rng(0)
    catalog.append(
        "ns.raw",
        Table(
            {
                "eventTime": np.arange(1000, dtype=np.int64),
                "c1": rng.standard_normal(1000),
                "c3": rng.integers(0, 100, 1000).astype(np.int64),
            }
        ),
    )
    return store, catalog


# ---------------------------------------------------------------- sorted_output
def test_sorted_output_without_sort_key_in_projection(env):
    """``sorted_output=True`` must hold even when ``eventTime`` is not among
    the projected columns: sort on the physical columns (which always carry
    the key), THEN project it away — silently returning cache-hit chunks in
    plan order is not an option."""
    store, catalog = env
    ex = ScanExecutor(store, catalog, cache=DifferentialCache())
    # prime the cache with a mid-table window so the later spanning scan
    # assembles out-of-order chunks (cache hit first, residual after)
    ex.scan("ns.raw", ["c1"], IntervalSet.of((500, 600)))
    out = ex.scan("ns.raw", ["c1"], IntervalSet.of((0, 1000)), sorted_output=True)
    assert out.column_names == ("c1",)  # key still projected away

    ref = ScanExecutor(store, catalog, cache=NoCache())
    want = (
        ref.scan("ns.raw", ["c1", "eventTime"], IntervalSet.of((0, 1000)))
        .combine()
        .sort_by("eventTime")
        .column("c1")
    )
    np.testing.assert_array_equal(out.combine().column("c1"), want)


def test_sorted_output_with_sort_key_still_sorted(env):
    store, catalog = env
    ex = ScanExecutor(store, catalog, cache=DifferentialCache())
    ex.scan("ns.raw", ["c1"], IntervalSet.of((300, 400)))
    out = ex.scan(
        "ns.raw", ["c1", "eventTime"], IntervalSet.of((0, 700)), sorted_output=True
    )
    keys = out.combine().column("eventTime")
    assert np.all(np.diff(keys) >= 0)


# ------------------------------------------------------------- result cache
def test_result_cache_predicate_id_reuse_no_false_hit(env):
    """The memo key must hold the predicate OBJECT: keying on ``id()`` gave
    false hits when CPython recycled a collected predicate's address for
    the next one."""
    store, catalog = env
    ex = ResultCachingExecutor(store, catalog)

    def run(thresh):
        # fresh predicate each call; the previous one is garbage by then, so
        # with an id() key CPython routinely hands the new closure the SAME
        # address -> false memo hit serving the previous threshold's rows
        def pred(t):
            return t.column("c3") >= thresh

        out = ex.scan("ns.raw", ["c3"], IntervalSet.of((0, 1000)), predicate=pred)
        return np.asarray(out.combine().column("c3"))

    ref = ScanExecutor(store, catalog, cache=NoCache())
    full = ref.scan("ns.raw", ["c3"], IntervalSet.of((0, 1000))).combine().column("c3")
    for thresh in (10, 50, 90, 50):
        got = run(thresh)
        want = np.sort(full[full >= thresh])
        np.testing.assert_array_equal(np.sort(got), want)


def test_result_cache_same_predicate_object_still_hits(env):
    store, catalog = env
    ex = ResultCachingExecutor(store, catalog)
    pred = lambda t: t.column("c3") >= 50
    ex.scan("ns.raw", ["c3"], IntervalSet.of((0, 1000)), predicate=pred)
    before = store.stats.bytes_read
    ex.scan("ns.raw", ["c3"], IntervalSet.of((0, 1000)), predicate=pred)
    assert ex.hits == 1
    assert store.stats.bytes_read == before
