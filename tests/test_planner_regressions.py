"""Regression tests for ScanExecutor / ResultCachingExecutor edge cases:
sorted output when the sort key is projected away, and result-cache memo
keys surviving predicate garbage collection."""

import numpy as np
import pytest

from repro.core.baselines import NoCache
from repro.core.cache import DifferentialCache
from repro.core.columnar import Table
from repro.core.intervals import IntervalSet
from repro.core.planner import ResultCachingExecutor, ScanExecutor
from repro.lake.catalog import Catalog
from repro.lake.s3sim import ObjectStore

SCHEMA = {"eventTime": "<i8", "c1": "<f8", "c3": "<i8"}


@pytest.fixture()
def env(tmp_path):
    store = ObjectStore(str(tmp_path / "s3"))
    catalog = Catalog(store, rows_per_fragment=64)
    catalog.create_table("ns", "raw", SCHEMA, "eventTime")
    rng = np.random.default_rng(0)
    catalog.append(
        "ns.raw",
        Table(
            {
                "eventTime": np.arange(1000, dtype=np.int64),
                "c1": rng.standard_normal(1000),
                "c3": rng.integers(0, 100, 1000).astype(np.int64),
            }
        ),
    )
    return store, catalog


# ---------------------------------------------------------------- sorted_output
def test_sorted_output_without_sort_key_in_projection(env):
    """``sorted_output=True`` must hold even when ``eventTime`` is not among
    the projected columns: sort on the physical columns (which always carry
    the key), THEN project it away — silently returning cache-hit chunks in
    plan order is not an option."""
    store, catalog = env
    ex = ScanExecutor(store, catalog, cache=DifferentialCache())
    # prime the cache with a mid-table window so the later spanning scan
    # assembles out-of-order chunks (cache hit first, residual after)
    ex.scan("ns.raw", ["c1"], IntervalSet.of((500, 600)))
    out = ex.scan("ns.raw", ["c1"], IntervalSet.of((0, 1000)), sorted_output=True)
    assert out.column_names == ("c1",)  # key still projected away

    ref = ScanExecutor(store, catalog, cache=NoCache())
    want = (
        ref.scan("ns.raw", ["c1", "eventTime"], IntervalSet.of((0, 1000)))
        .combine()
        .sort_by("eventTime")
        .column("c1")
    )
    np.testing.assert_array_equal(out.combine().column("c1"), want)


def test_sorted_output_with_sort_key_still_sorted(env):
    store, catalog = env
    ex = ScanExecutor(store, catalog, cache=DifferentialCache())
    ex.scan("ns.raw", ["c1"], IntervalSet.of((300, 400)))
    out = ex.scan(
        "ns.raw", ["c1", "eventTime"], IntervalSet.of((0, 700)), sorted_output=True
    )
    keys = out.combine().column("eventTime")
    assert np.all(np.diff(keys) >= 0)


# ------------------------------------------------------------- result cache
def test_result_cache_predicate_id_reuse_no_false_hit(env):
    """The memo key must hold the predicate OBJECT: keying on ``id()`` gave
    false hits when CPython recycled a collected predicate's address for
    the next one."""
    store, catalog = env
    ex = ResultCachingExecutor(store, catalog)

    def run(thresh):
        # fresh predicate each call; the previous one is garbage by then, so
        # with an id() key CPython routinely hands the new closure the SAME
        # address -> false memo hit serving the previous threshold's rows
        def pred(t):
            return t.column("c3") >= thresh

        out = ex.scan("ns.raw", ["c3"], IntervalSet.of((0, 1000)), predicate=pred)
        return np.asarray(out.combine().column("c3"))

    ref = ScanExecutor(store, catalog, cache=NoCache())
    full = ref.scan("ns.raw", ["c3"], IntervalSet.of((0, 1000))).combine().column("c3")
    for thresh in (10, 50, 90, 50):
        got = run(thresh)
        want = np.sort(full[full >= thresh])
        np.testing.assert_array_equal(np.sort(got), want)


def test_cache_chunks_counts_only_hit_views(env):
    """A pure cache miss must report ``cache_chunks == 0``: the old code
    counted ``len(chunks)`` *after* appending the fresh residual, so a cold
    scan claimed one cache chunk.  Residual volume now lands in the separate
    ``residual_rows`` field."""
    store, catalog = env
    ex = ScanExecutor(store, catalog, cache=DifferentialCache())

    ex.scan("ns.raw", ["c1"], IntervalSet.of((0, 200)))  # cold: pure miss
    cold = ex.reports[-1]
    assert cold.cache_chunks == 0
    assert cold.residual_rows == 200

    ex.scan("ns.raw", ["c1"], IntervalSet.of((0, 200)))  # warm: pure hit
    warm = ex.reports[-1]
    assert warm.cache_chunks == 1
    assert warm.residual_rows == 0

    ex.scan("ns.raw", ["c1"], IntervalSet.of((0, 300)))  # partial
    part = ex.reports[-1]
    assert part.cache_chunks == 1
    assert part.residual_rows == 100


def test_concurrent_scans_and_appends_stay_correct(env):
    """Threads doing overlapping scans while others append: every scan's
    result must equal an uncached scan of the snapshot it planned against.
    Regression for slicing hit elements OUTSIDE the executor lock — a
    concurrent insert could merge/evict the planned element between the plan
    and the slice."""
    import threading

    store, catalog = env
    ex = ScanExecutor(store, catalog, cache=DifferentialCache())
    errors = []
    stop = threading.Event()

    def scanner(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(30):
                lo = int(rng.integers(0, 900))
                hi = lo + int(rng.integers(1, 100))
                out = ex.scan("ns.raw", ["c1", "eventTime"], IntervalSet.of((lo, hi)))
                t = out.combine()
                snap = catalog.snapshot("ns.raw", ex.reports[-1].snapshot_id)
                ref = ScanExecutor(store, catalog, cache=NoCache())
                want = ref.scan(
                    "ns.raw", ["c1", "eventTime"], IntervalSet.of((lo, hi)),
                    snapshot_id=snap.snapshot_id,
                ).combine()
                got = sorted(zip(t.column("eventTime").tolist(), t.column("c1").tolist()))
                exp = sorted(zip(want.column("eventTime").tolist(), want.column("c1").tolist()))
                if got != exp:
                    errors.append((lo, hi, len(got), len(exp)))
        except Exception as e:  # pragma: no cover - failure reporting
            errors.append(repr(e))

    def appender():
        nxt = 1000
        while not stop.is_set():
            rng = np.random.default_rng(nxt)
            catalog.append(
                "ns.raw",
                Table(
                    {
                        "eventTime": np.arange(nxt, nxt + 40, dtype=np.int64),
                        "c1": rng.standard_normal(40),
                        "c3": rng.integers(0, 100, 40).astype(np.int64),
                    }
                ),
            )
            nxt += 40

    threads = [threading.Thread(target=scanner, args=(s,)) for s in range(4)]
    app = threading.Thread(target=appender)
    app.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    app.join()
    assert not errors, errors[:5]


def test_result_cache_lru_byte_budget(env):
    """The result-cache baseline must hold its memo under ``max_bytes`` with
    LRU eviction — an unbounded memo skews Table-II comparisons."""
    store, catalog = env
    ex = ResultCachingExecutor(store, catalog, max_bytes=8_000)
    for lo in range(0, 1000, 100):
        ex.scan("ns.raw", ["c1", "c3"], IntervalSet.of((lo, lo + 100)))
    assert ex.nbytes <= 8_000
    assert ex.evictions > 0

    # LRU order: the most recently used entry survives, the eldest is gone
    before = store.stats.bytes_read
    ex.scan("ns.raw", ["c1", "c3"], IntervalSet.of((900, 1000)))  # still memoized
    assert store.stats.bytes_read == before
    ex.scan("ns.raw", ["c1", "c3"], IntervalSet.of((0, 100)))  # evicted: refetch
    assert store.stats.bytes_read > before
    # correctness after eviction
    got = ex.scan("ns.raw", ["c1"], IntervalSet.of((0, 1000))).combine()
    ref = ScanExecutor(store, catalog, cache=NoCache())
    want = ref.scan("ns.raw", ["c1"], IntervalSet.of((0, 1000))).combine()
    np.testing.assert_array_equal(
        np.sort(got.column("c1")), np.sort(want.column("c1"))
    )


def test_result_cache_oversize_result_does_not_wipe_memo(env):
    """A single result larger than the whole budget passes through unretained
    — it must not evict every hot entry on its way."""
    store, catalog = env
    ex = ResultCachingExecutor(store, catalog, max_bytes=4_000)
    ex.scan("ns.raw", ["c1"], IntervalSet.of((0, 100)))  # 800 B, hot
    hot_bytes = ex.nbytes
    assert 0 < hot_bytes <= 4_000
    ex.scan("ns.raw", ["c1", "c3", "eventTime"], IntervalSet.of((0, 1000)))  # 24 kB
    assert ex.nbytes == hot_bytes  # memo untouched, oversize not retained
    assert ex.evictions == 0
    before = store.stats.bytes_read
    ex.scan("ns.raw", ["c1"], IntervalSet.of((0, 100)))  # still memoized
    assert store.stats.bytes_read == before


def test_result_cache_same_predicate_object_still_hits(env):
    store, catalog = env
    ex = ResultCachingExecutor(store, catalog)
    pred = lambda t: t.column("c3") >= 50
    ex.scan("ns.raw", ["c3"], IntervalSet.of((0, 1000)), predicate=pred)
    before = store.stats.bytes_read
    ex.scan("ns.raw", ["c3"], IntervalSet.of((0, 1000)), predicate=pred)
    assert ex.hits == 1
    assert store.stats.bytes_read == before
