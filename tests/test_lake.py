"""Tests for the lakehouse substrate: object store, fragments, catalog."""

import json

import numpy as np
import pytest

from repro.core.columnar import Table
from repro.lake.catalog import Catalog, CommitConflict
from repro.lake.fragments import read_fragment_columns
from repro.lake.s3sim import LatencyModel, ObjectStore


def events_table(lo, hi, seed=0):
    n = hi - lo
    rng = np.random.default_rng(seed)
    return Table(
        {
            "eventTime": np.arange(lo, hi, dtype=np.int64),
            "c1": rng.standard_normal(n),
            "c2": rng.standard_normal(n),
            "c3": rng.integers(0, 100, n).astype(np.int64),
        }
    )


@pytest.fixture()
def store(tmp_path):
    return ObjectStore(str(tmp_path / "s3"))


@pytest.fixture()
def catalog(store):
    return Catalog(store, rows_per_fragment=100)


def test_object_store_accounting(store):
    store.put("a/b.bin", b"x" * 1000)
    assert store.stats.bytes_written == 1000
    data = store.get_range("a/b.bin", 100, 50)
    assert data == b"x" * 50
    assert store.stats.bytes_read == 50
    assert store.stats.get_requests == 1
    assert store.stats.simulated_seconds > 0


def test_object_store_immutability(store):
    store.put("k", b"1")
    with pytest.raises(FileExistsError):
        store.put("k", b"2")


def test_latency_model_monotone():
    lm = LatencyModel()
    assert lm.seconds(10**9) > lm.seconds(10**6) > lm.seconds(0)


def test_create_append_scan_roundtrip(store, catalog):
    catalog.create_table(
        "ns", "raw", {"eventTime": "<i8", "c1": "<f8", "c2": "<f8", "c3": "<i8"}, "eventTime"
    )
    snap = catalog.append("ns.raw", events_table(0, 250))
    assert snap.operation == "append"
    assert len(snap.fragments) == 3  # 250 rows @ 100/frag
    # fragment min/max pruning metadata is exact
    frags = sorted(snap.fragments, key=lambda f: f.key_min)
    assert frags[0].key_min == 0 and frags[0].key_max == 99
    assert frags[-1].key_max == 249
    # projection reads only requested chunk bytes
    before = store.stats.bytes_read
    tbl = read_fragment_columns(store, frags[0], ["c1"])
    assert tbl.num_rows == 100
    assert store.stats.bytes_read - before == frags[0].column_meta("c1").nbytes


def test_snapshot_isolation_and_time_travel(store, catalog):
    catalog.create_table("ns", "t", {"eventTime": "<i8", "c1": "<f8", "c2": "<f8", "c3": "<i8"}, "eventTime")
    s1 = catalog.append("ns.t", events_table(0, 100))
    s2 = catalog.append("ns.t", events_table(100, 200))
    assert catalog.current_snapshot("ns.t").snapshot_id == s2.snapshot_id
    # time travel: the older snapshot still sees only its fragments
    old = catalog.snapshot("ns.t", s1.snapshot_id)
    assert len(old.fragments) == 1
    assert len(s2.fragments) == 2
    hist = catalog.history("ns.t")
    assert [h.sequence for h in hist] == [0, 1, 2]


def test_optimistic_commit_conflict(store, catalog):
    catalog.create_table("ns", "t", {"eventTime": "<i8", "c1": "<f8", "c2": "<f8", "c3": "<i8"}, "eventTime")
    s1 = catalog.append("ns.t", events_table(0, 50))
    catalog.append("ns.t", events_table(50, 100))  # someone else commits
    with pytest.raises(CommitConflict):
        catalog.append("ns.t", events_table(100, 150), expected_parent=s1.snapshot_id)


def test_overwrite_range_drops_and_rewrites(store, catalog):
    catalog.create_table("ns", "t", {"eventTime": "<i8", "c1": "<f8", "c2": "<f8", "c3": "<i8"}, "eventTime")
    catalog.append("ns.t", events_table(0, 300))
    snap = catalog.overwrite_range("ns.t", 100, 150)  # delete [100,150)
    total = sum(f.row_count for f in snap.fragments)
    assert total == 250
    # no live fragment claims keys inside the deleted window exclusively
    for f in snap.fragments:
        assert not (f.key_min >= 100 and f.key_max < 150)


def test_fragments_are_immutable_blobs(store, catalog):
    catalog.create_table("ns", "t", {"eventTime": "<i8", "c1": "<f8", "c2": "<f8", "c3": "<i8"}, "eventTime")
    s1 = catalog.append("ns.t", events_table(0, 100))
    s2 = catalog.overwrite_range("ns.t", 0, 50)
    # old snapshot's fragment blob still readable (time travel works)
    old_frag = s1.fragments[0]
    tbl = read_fragment_columns(store, old_frag, ["eventTime"])
    assert tbl.num_rows == 100
