"""Tiered differential cache (ISSUE 5 tentpole): eviction demotes elements
to an IPC spill tier in the object store, plans treat spilled windows as
hits and promote them back via mmap (zero-copy until touched), a store over
a populated spill root restarts warm, and a RAM budget below the working
set still serves the full workload from the spill tier.
"""

import tempfile
import threading
import time

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.cache import DifferentialStore, FragmentPin
from repro.core.columnar import Table, concat_tables
from repro.core.intervals import Interval, IntervalSet
from repro.core.spill import SpillTier
from repro.lake.s3sim import ObjectStore
from repro.service import PipelineService, SharedStore

from test_service import (
    TABLE,
    assert_outputs_bitwise_equal,
    cold_reference,
    events_table,
    pipeline_project,
    write_events,
)


def _tbl(lo, hi, seed=0):
    rng = np.random.default_rng(seed + lo)
    return Table(
        {
            "k": np.arange(lo, hi, dtype=np.int64),
            "x": rng.standard_normal(hi - lo),
            "y": rng.integers(0, 1000, hi - lo).astype(np.int32),
        }
    )


def _insert(store, sig, lo, hi, seed=0, tenant=None):
    return store.insert_window(
        signature=sig,
        table="t",
        sort_key="k",
        window=IntervalSet([Interval(lo, hi)]),
        data=_tbl(lo, hi, seed),
        tenant=tenant,
    )


def _plan(store, sig, lo, hi):
    return store.plan_window(
        signature=sig,
        window=IntervalSet([Interval(lo, hi)]),
        columns=(),
        cost_fn=lambda w: w.measure(),
    )


# ------------------------------------------------------------- demote/promote
def test_eviction_demotes_instead_of_dropping(tmp_path):
    store = SharedStore(max_bytes=3000, spill_root=str(tmp_path / "spill"))
    a = _insert(store, "sig", 0, 100)  # ~2000B
    b = _insert(store, "sig", 200, 300)  # over budget -> a demoted, not gone
    assert a.data is None and a.spill is not None
    assert b.data is not None
    assert store.demotions == 1
    assert len(store.elements("sig")) == 2  # the index still knows a
    assert store.nbytes <= 3000
    assert store.spill_nbytes > 0


def test_spilled_window_is_a_hit_and_promotes_via_mmap(tmp_path):
    store = SharedStore(max_bytes=3000, spill_root=str(tmp_path / "spill"))
    a = _insert(store, "sig", 0, 100)
    _insert(store, "sig", 200, 300)
    assert a.data is None
    plan = _plan(store, "sig", 10, 60)
    assert plan.fully_cached, "spilled windows must plan as hits"
    assert a.data is not None, "the hit element was promoted"
    assert plan.promoted_spill_bytes == a.data.nbytes
    assert store.promotions == 1
    # bitwise-equal payload, and the served views are zero-copy over the
    # promoted (memory-mapped) buffers
    views = plan.hits[0].element.slice_window(plan.hits[0].window, ("k", "x", "y"))
    ref = _tbl(0, 100).slice(10, 60)
    got = views[0]
    for col in ("k", "x", "y"):
        np.testing.assert_array_equal(got.column(col), ref.column(col))
        assert np.shares_memory(got.column(col), a.data.column(col))
    assert not got.column("x").flags.writeable  # mmap'd buffers stay frozen


def test_redemote_after_promote_is_free(tmp_path):
    """An element, once spilled, never changes: demoting it again reuses the
    existing spill copy (no second write)."""
    store = SharedStore(spill_root=str(tmp_path / "spill"))
    a = _insert(store, "siga", 0, 100)
    store.demote_all()
    assert a.data is None and store.spill.spills == 1
    _plan(store, "siga", 0, 100)  # promote a back
    assert a.data is not None
    store.demote_all()
    assert a.data is None
    assert store.spill.spills == 1, "clean spill copy was reused"


def test_spill_gc_on_invalidate_and_merge(tmp_path):
    store = SharedStore(spill_root=str(tmp_path / "spill"))
    spill_store = store.spill.store
    a = _insert(store, "sig", 0, 100)
    store.demote_all()
    assert a.spill is not None
    assert len(spill_store.list("_spill/manifest/")) == 1
    # promoting a and inserting the adjacent window merges the two into one
    # fresh element: a's now-stale spill copy must be GC'd
    _plan(store, "sig", 0, 100)
    _insert(store, "sig", 100, 200)
    assert len(store.elements("sig")) == 1
    assert spill_store.list("_spill/manifest/") == []
    # invalidation reclaims both tiers
    _insert(store, "gone", 400, 600)
    store.demote_all()
    assert len(spill_store.list("_spill/manifest/")) == 2
    store.invalidate("gone")
    leftover = [
        k for k in spill_store.list("_spill/manifest/")
        if b'"gone"' in spill_store.get(k)
    ]
    assert leftover == []
    assert len(spill_store.list("_spill/manifest/")) == 1


# ---------------------------------------------------- property: round-trip
@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=0, max_value=500),
    st.integers(min_value=1, max_value=500),
    st.integers(min_value=0, max_value=10_000),
)
def test_spill_roundtrip_property(lo, width, seed):
    """evict -> demote -> promote is bitwise-equal for arbitrary windows and
    payloads, and promoted views share memory with the mmap'd buffers."""
    hi = lo + width
    with tempfile.TemporaryDirectory() as tmp:
        store = DifferentialStore(
            max_bytes=1, spill=SpillTier(ObjectStore(tmp))
        )  # any insert immediately exceeds the budget and demotes
        elem = _insert(store, "sig", lo, hi, seed=seed)
        assert elem.data is None and elem.spill is not None
        plan = _plan(store, "sig", lo, hi)
        assert plan.fully_cached
        ref = _tbl(lo, hi, seed=seed)
        views = plan.hits[0].element.slice_window(
            plan.hits[0].window, ("k", "x", "y")
        )
        assert sum(v.num_rows for v in views) == ref.num_rows
        got = views[0]
        for col in ("k", "x", "y"):
            np.testing.assert_array_equal(got.column(col), ref.column(col))
            assert got.column(col).dtype == ref.column(col).dtype
            assert np.shares_memory(got.column(col), elem.data.column(col))


@settings(max_examples=25, deadline=None)
@given(
    st.lists(
        st.tuples(st.integers(0, 400), st.integers(1, 60)), min_size=1, max_size=4
    ),
    st.lists(
        st.tuples(st.integers(0, 400), st.integers(0, 30), st.booleans()),
        max_size=4,
    ),
    st.integers(min_value=0, max_value=10_000),
)
def test_spill_manifest_roundtrip_multi_interval_labeled_pins(pairs, pin_specs, seed):
    """Restart-from-manifest round-trips MULTI-interval windows and LABELED
    fragment pins (multi-input elements pin several leaf tables; unlabeled
    pins must come back as ``table=None`` — the back-compat manifest form)."""
    window = IntervalSet.of(*[(lo, lo + w) for lo, w in pairs])
    lo, hi = window.span().lo, window.span().hi
    pins = tuple(
        FragmentPin(f"f-{i}", p_lo, p_lo + p_w, f"ns.t{i}" if labeled else None)
        for i, (p_lo, p_w, labeled) in enumerate(pin_specs)
    )
    with tempfile.TemporaryDirectory() as tmp:
        obj = ObjectStore(tmp)
        store = DifferentialStore(spill=SpillTier(obj))
        store.insert_window("sig", "t", "k", window, _tbl(lo, hi, seed=seed), pins=pins)
        store.demote_all()

        fresh = DifferentialStore(spill=SpillTier(obj))
        assert fresh.spill_restored == 1
        (elem,) = fresh.elements("sig")
        assert elem.window == window
        assert elem.pins == pins  # fragment ids, key stats AND table labels
        plan = fresh.plan_window(
            "sig", window, (), lambda w: w.measure()
        )
        assert plan.fully_cached
        ref = _tbl(lo, hi, seed=seed)
        got = concat_tables(
            [
                v
                for h in plan.hits
                for v in h.element.slice_window(h.window, ("k", "x", "y"))
            ]
        )
        # the insert stored span rows; hits cover exactly the window's rows
        keys = ref.column("k")
        mask = np.zeros(ref.num_rows, dtype=bool)
        for iv in window:
            mask |= (keys >= iv.lo) & (keys < iv.hi)
        expect = ref.filter(mask)
        for col in ("k", "x", "y"):
            np.testing.assert_array_equal(got.column(col), expect.column(col))


# ------------------------------------------------------------- warm restarts
def test_restart_warm_from_manifests(tmp_path):
    root = str(tmp_path / "spill")
    store = SharedStore(spill_root=root)
    a = _insert(store, "siga", 0, 100, seed=1, tenant="alice")
    b = _insert(store, "sigb", 50, 250, seed=2, tenant="bob")
    store.demote_all()
    assert a.data is None and b.data is None

    fresh = SharedStore(spill_root=root)
    assert fresh.spill_restored == 2
    assert fresh.nbytes == 0, "restored elements start demoted"
    assert {e.signature for e in fresh.elements()} == {"siga", "sigb"}
    assert {e.owner for e in fresh.elements()} == {"alice", "bob"}
    plan = _plan(fresh, "sigb", 50, 250)
    assert plan.fully_cached
    views = plan.hits[0].element.slice_window(plan.hits[0].window, ("k", "x", "y"))
    ref = _tbl(50, 250, seed=2)
    for col in ("k", "x", "y"):
        np.testing.assert_array_equal(views[0].column(col), ref.column(col))


def test_restore_skips_and_gcs_damaged_manifests(tmp_path):
    """Regression (ISSUE 10 satellite): a crash can leave the spill prefix
    with manifests whose payload is gone or truncated, or whose own JSON
    never finished uploading.  restore() used to trust every manifest and
    blow up the whole restart; it must skip + GC the bad entries, count them
    as quarantined, and restore the rest."""
    import json

    root = str(tmp_path / "spill")
    store = SharedStore(spill_root=root)
    spans = {"siga": (0, 100), "sigb": (100, 200), "sigc": (200, 300), "sigd": (300, 400)}
    for i, (sig, (lo, hi)) in enumerate(spans.items()):
        _insert(store, sig, lo, hi, seed=i, tenant="alice")
    store.demote_all()

    raw = ObjectStore(root)
    manifests = sorted(raw.list("_spill/manifest/"))
    assert len(manifests) == 4

    def rewrite(key, data):
        raw.delete(key)
        raw.put(key, data)

    # payload deleted outright
    raw.delete(json.loads(raw.get(manifests[0]))["data_key"])
    # payload truncated (torn upload)
    dk = json.loads(raw.get(manifests[1]))["data_key"]
    rewrite(dk, raw.get(dk)[:-7])
    # the manifest itself never finished uploading
    torn = raw.get(manifests[2])
    rewrite(manifests[2], torn[: len(torn) // 2])
    survivor_sig = json.loads(raw.get(manifests[3]))["signature"]

    fresh = SharedStore(spill_root=root)
    assert fresh.spill_restored == 1
    assert {e.signature for e in fresh.elements()} == {survivor_sig}
    assert fresh.stats()["spill_quarantined"] == 3
    # the damaged entries are GC'd, not left to poison the next restart —
    # including the payload orphaned by the torn manifest upload
    assert raw.list("_spill/manifest/") == [manifests[3]]
    assert len(raw.list("_spill/data/")) == 1
    assert fresh.spill.orphans == 1
    # the survivor still serves its window
    plan = _plan(fresh, survivor_sig, *spans[survivor_sig])
    assert plan.fully_cached


def test_service_restart_is_warm_and_bitwise_equal(tmp_path):
    """A restarted service over a populated spill root replays the workload
    with (far) fewer store bytes and bitwise-identical outputs — the
    BENCH_5 claim at test scale."""
    rows = 1500
    root = str(tmp_path / "svc")
    with PipelineService(root, workers=2, rows_per_fragment=256, spill=True) as svc:
        write_events(svc.catalog, 0, rows)
        r_cold = svc.session("alice").run(pipeline_project(hi=rows - 1))
        assert r_cold.bytes_from_store > 0

    with PipelineService(root, workers=2, rows_per_fragment=256, spill=True) as svc2:
        assert svc2.model_store.spill_restored > 0
        assert svc2.scan_cache.spill_restored > 0
        r_warm = svc2.session("alice").run(pipeline_project(hi=rows - 1))

    assert_outputs_bitwise_equal(r_warm, r_cold)
    assert r_warm.rows_to_user_fns == 0, "fully served from the spill tier"
    assert r_warm.bytes_from_spill > 0
    assert r_warm.bytes_from_store * 5 <= r_cold.bytes_from_store


# ------------------------------------------- capacity: RAM below working set
def test_ram_budget_below_working_set_serves_from_spill(tmp_path):
    """Acceptance: a SharedStore with max_bytes far below the working set
    serves the full workload correctly — capacity is the spill tier, with
    RAM as a churn window."""
    rows = 1500
    with PipelineService(
        str(tmp_path / "svc"),
        workers=2,
        rows_per_fragment=256,
        model_cache_bytes=20_000,  # working set is several x this
        scan_cache_bytes=20_000,
        spill=True,
    ) as svc:
        write_events(svc.catalog, 0, rows)
        results = [
            svc.session("alice").run(pipeline_project(hi=hi))
            for hi in (rows - 1, 600, rows - 1, 1000, rows - 1)
        ]
        assert svc.model_store.demotions > 0, "budget must actually bite"
        assert svc.model_store.promotions > 0, "spilled windows must serve"
        # the budget is soft only by the LAST run's in-flight working set
        # (plan-time eviction protects the hits a run is slicing)
        assert (
            svc.model_store.nbytes
            <= 20_000 + results[-1].bytes_from_model_cache
        )

    for i, (hi, res) in enumerate(zip((rows - 1, 600, rows - 1, 1000, rows - 1), results)):
        ref = cold_reference(tmp_path, f"cold-{i}-{hi}",
                             pipeline_project(hi=hi), rows=rows)
        assert_outputs_bitwise_equal(res, ref)


# ------------------------------------------------------------ threaded stress
def test_threaded_stress_spills_promotions_restarts(tmp_path):
    """Concurrent runs + appends + constant demote/promote churn on one
    spill-backed store, THEN a restart over the same root: every output —
    before and after the restart — is bitwise-equal to a cold run against
    the session's pinned snapshot."""
    rows = 1200
    root = str(tmp_path / "svc")
    his = [399, 799, 1199, 599, 999, 1199]

    with PipelineService(
        root,
        workers=4,
        rows_per_fragment=128,
        model_cache_bytes=30_000,  # way under the working set: constant churn
        scan_cache_bytes=30_000,
        spill=True,
    ) as svc:
        write_events(svc.catalog, 0, rows)
        readers = [svc.session(t) for t in ("alice", "bob")]
        stop = threading.Event()

        def appender():
            session = svc.session("writer")
            lo = rows
            while not stop.is_set():
                session.append(TABLE, events_table(lo, lo + 64, seed=7))
                lo += 64
                time.sleep(0.002)

        wt = threading.Thread(target=appender)
        wt.start()
        try:
            handles = [
                svc.submit(readers[i % 2].tenant_id, pipeline_project(hi=hi))
                for i, hi in enumerate(his)
            ]
            svc.drain(120)
        finally:
            stop.set()
            wt.join()

        refs = {}
        for hi, h in zip(his, handles):
            assert h.state == "DONE", h.error
            if hi not in refs:
                refs[hi] = cold_reference(
                    tmp_path, f"spill-cold-{hi}", pipeline_project(hi=hi), rows=rows
                )
            assert_outputs_bitwise_equal(h.result, refs[hi])
        assert svc.model_store.demotions > 0
        assert svc.model_store.promotions > 0

    # restart over the same root: runs must still be correct (warm or not)
    with PipelineService(
        root, workers=2, rows_per_fragment=128,
        model_cache_bytes=30_000, scan_cache_bytes=30_000, spill=True,
    ) as svc2:
        assert svc2.model_store.spill_restored > 0
        for hi in (399, 1199):
            res = svc2.session("carol").run(pipeline_project(hi=hi))
            ref = cold_reference(
                tmp_path, f"spill-cold2-{hi}", pipeline_project(hi=hi), rows=rows
            )
            assert_outputs_bitwise_equal(res, ref)


# --------------------------------------------------- acceptance: BENCH_5 gate
def test_bench5_acceptance():
    """The BENCH_5 scenario (same code CI smokes): a restarted service over
    a populated spill root replays the workload with >=5x fewer store bytes
    and bitwise-equal outputs (asserted inside run), and N concurrent
    identical runs execute the residual user fns exactly once."""
    from benchmarks import bench5_tiered as b5

    result = b5.run(rows=4000, tenants=3)
    assert result["restart_bytes_ratio"] >= 5.0, result
    assert result["coalesced"]["duplicate_rows"] == 0, result
    assert result["warm_restart"]["elements_restored"] > 0
    assert result["warm_restart"]["rows_to_user_fns"] == 0
